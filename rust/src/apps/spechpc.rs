//! SPEChpc-2021-like benchmarks: MPI + OpenMP target offload (the
//! configuration the paper runs on Aurora's 6 GPUs and Polaris' 4 GPUs).
//!
//! Each benchmark runs one MPI rank per GPU; every iteration does a halo
//! exchange with its ring neighbours, host↔device transfers, one or more
//! kernel submissions, and a residual allreduce — the communication/
//! compute skeleton of the real suite, with per-app parameters chosen to
//! reproduce the archetypes (505.lbm stencil-bound, 521.miniswp
//! launch-storm, 534.hpgmgfv trace-heaviest, ...).

use super::{scaled, Workload};
use crate::device::{AllocKind, Node};
use crate::intercept::mpi::{Datatype, MpiWorld, Op};
use crate::intercept::omp::{OmpConfig, OmpRuntime};
use crate::intercept::ze::ZeDriver;
use crate::runtime::executor::f32_to_bytes;
use crate::util::Rng;
use std::sync::Arc;

/// One SPEChpc-like app's parameters.
#[derive(Debug, Clone)]
pub struct SpecApp {
    /// Benchmark id (paper naming).
    pub name: &'static str,
    /// Kernel(s) submitted each iteration.
    pub kernels: &'static [&'static str],
    /// Elements per device buffer.
    pub elems: usize,
    /// Kernel submissions per iteration (launch-rate knob).
    pub launches_per_iter: u32,
    /// Halo-exchange message bytes.
    pub halo_bytes: usize,
    /// Iterations.
    pub iters: u32,
}

/// The 9-app suite.
pub fn suite() -> Vec<Arc<dyn Workload>> {
    vec![
        Arc::new(SpecApp {
            name: "505.lbm",
            kernels: &["stencil"],
            elems: 512 * 512,
            launches_per_iter: 2,
            halo_bytes: 512 * 4,
            iters: 10,
        }),
        Arc::new(SpecApp {
            name: "513.soma",
            kernels: &["saxpy"],
            elems: 1 << 20,
            launches_per_iter: 1,
            halo_bytes: 4096,
            iters: 12,
        }),
        Arc::new(SpecApp {
            name: "518.tealeaf",
            kernels: &["stencil"],
            elems: 512 * 512,
            launches_per_iter: 3,
            halo_bytes: 512 * 4,
            iters: 8,
        }),
        Arc::new(SpecApp {
            name: "519.clvleaf",
            kernels: &["stencil"],
            elems: 512 * 512,
            launches_per_iter: 2,
            halo_bytes: 2048,
            iters: 10,
        }),
        Arc::new(SpecApp {
            name: "521.miniswp",
            kernels: &["xent"],
            elems: 256 * 2048,
            launches_per_iter: 6,
            halo_bytes: 1024,
            iters: 8,
        }),
        Arc::new(SpecApp {
            name: "528.pot3d",
            kernels: &["matmul"],
            elems: 256 * 256,
            launches_per_iter: 2,
            halo_bytes: 256 * 4,
            iters: 10,
        }),
        Arc::new(SpecApp {
            name: "532.sph_exa",
            kernels: &["lrn"],
            elems: 32 * 64 * 256,
            launches_per_iter: 2,
            halo_bytes: 8192,
            iters: 10,
        }),
        Arc::new(SpecApp {
            name: "534.hpgmgfv",
            kernels: &["stencil", "saxpy", "conv1d"],
            elems: 512 * 512,
            launches_per_iter: 4,
            halo_bytes: 4096,
            iters: 8,
        }),
        Arc::new(SpecApp {
            name: "535.weather",
            kernels: &["conv1d"],
            elems: 64 * 4096,
            launches_per_iter: 2,
            halo_bytes: 4096,
            iters: 10,
        }),
    ]
}

/// Argument pointers for one kernel, given a generic in/out buffer pair
/// plus small auxiliary buffers (allocated by the rank).
fn kernel_args(kernel: &str, din: u64, dout: u64, aux: &[u64]) -> Vec<u64> {
    match kernel {
        "stencil" | "lrn" => vec![din, dout],
        "saxpy" => vec![aux[0], din, din, dout],
        "conv1d" => vec![din, aux[1], aux[2], dout],
        "matmul" => vec![din, aux[3], aux[4], dout],
        "xent" => vec![din, aux[5], dout],
        other => panic!("unknown kernel {other}"),
    }
}

/// Device bytes each kernel needs for its in/out buffers.
fn kernel_bytes(kernel: &str, elems: usize) -> u64 {
    let _ = kernel;
    (elems * 4) as u64
}

impl Workload for SpecApp {
    fn name(&self) -> &str {
        self.name
    }

    fn backend(&self) -> &'static str {
        "MPI"
    }

    fn run(&self, node: &Arc<Node>) {
        let ranks = node.gpus.len() as u32;
        let omp = OmpRuntime::new(ZeDriver::new(node.clone()), OmpConfig::default());
        let world = MpiWorld::new(ranks);
        let app = self.clone();
        let node2 = node.clone();
        world.run(move |comm| {
            let rank = comm.rank();
            let device = (rank % node2.gpus.len() as u32) as i32;
            let gpu = node2.gpu(device as u32);
            comm.mpi_init();
            let (_, size) = comm.mpi_comm_size();
            let (_, _my_rank) = comm.mpi_comm_rank();

            let bytes = kernel_bytes(app.kernels[0], app.elems);
            let (_, din) = omp.omp_target_alloc(bytes, device);
            let (_, dout) = omp.omp_target_alloc(bytes, device);
            // aux buffers sized for the largest consumers
            let aux: Vec<u64> = [
                4u64,                       // saxpy scalar a
                (33 * 4) as u64,            // conv taps
                bytes,                      // conv bias
                (256 * 256 * 4) as u64,     // matmul B
                (256 * 4) as u64,           // matmul bias
                (256 * 4) as u64,           // xent labels (i32)
            ]
            .iter()
            .map(|sz| omp.omp_target_alloc(*sz, device).1)
            .collect();

            let host = gpu.pool.alloc(AllocKind::Host, bytes).unwrap();
            let mut rng = Rng::new(0x5bec ^ rank as u64);
            let mut data = vec![0f32; app.elems];
            rng.fill_f32(&mut data);
            gpu.pool.write(host, &f32_to_bytes(&data)).unwrap();

            let right = (rank + 1) % size as u32;
            let left = (rank + size as u32 - 1) % size as u32;
            let halo_out = vec![rank as u8; app.halo_bytes];
            let mut halo_in = vec![0u8; app.halo_bytes];

            let iters = scaled(app.iters);
            for _ in 0..iters {
                // halo exchange (ring)
                comm.mpi_send(&halo_out, Datatype::Byte, right, 11);
                comm.mpi_recv(&mut halo_in, Datatype::Byte, left, 11);
                // offload
                omp.omp_target_memcpy(din, host, bytes, 0, 0, device, -1);
                for l in 0..app.launches_per_iter {
                    let k = app.kernels[(l as usize) % app.kernels.len()];
                    // kernels with their own shapes need their own buffers;
                    // din/dout are sized for kernels[0] — others use aux-
                    // sized launches on the same data when shapes allow.
                    if kernel_bytes(k, app.elems) == bytes {
                        omp.omp_target_submit(k, device, 8, &kernel_args(k, din, dout, &aux));
                    } else {
                        // mismatched shape: run on its own scratch
                        let kb = kernel_bytes(k, app.elems);
                        let (_, s_in) = omp.omp_target_alloc(kb, device);
                        let (_, s_out) = omp.omp_target_alloc(kb, device);
                        omp.omp_target_submit(k, device, 8, &kernel_args(k, s_in, s_out, &aux));
                        omp.omp_target_free(s_in, device);
                        omp.omp_target_free(s_out, device);
                    }
                }
                omp.omp_target_memcpy(host, dout, bytes, 0, 0, -1, device);
                // residual allreduce
                let local = data[0] as f64;
                let mut global = [0.0f64];
                comm.mpi_allreduce(&[local], &mut global, Op::Sum);
            }
            comm.mpi_barrier();
            omp.omp_target_free(din, device);
            omp.omp_target_free(dout, device);
            for a in aux {
                omp.omp_target_free(a, device);
            }
            let _ = gpu.pool.free(host);
            comm.mpi_finalize();
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::NodeConfig;
    use crate::tracer::session::test_support;

    #[test]
    fn lbm_runs_on_two_gpus_untraced() {
        let _g = test_support::lock();
        std::env::set_var("THAPI_APP_SCALE", "0.2");
        let node = crate::device::Node::new(NodeConfig {
            gpu_count: 2,
            ..NodeConfig::test_small()
        });
        let apps = suite();
        let lbm = apps.iter().find(|a| a.name() == "505.lbm").unwrap();
        lbm.run(&node);
        node.synchronize();
        std::env::remove_var("THAPI_APP_SCALE");
    }

    #[test]
    fn miniswp_traced_produces_mpi_and_omp_and_ze_events() {
        let _g = test_support::lock();
        std::env::set_var("THAPI_APP_SCALE", "0.2");
        let node = crate::device::Node::new(NodeConfig::test_small());
        crate::tracer::install_session(Default::default());
        let apps = suite();
        let app = apps.iter().find(|a| a.name() == "521.miniswp").unwrap();
        app.run(&node);
        node.synchronize();
        let session = crate::tracer::uninstall_session().unwrap();
        let trace = crate::tracer::btf::collect(&session, &[]);
        let parsed = crate::analysis::parse_trace(&trace).unwrap();
        let has = |p: &str| {
            crate::analysis::MessageSource::new(&parsed).any(|m| m.class.name.starts_with(p))
        };
        assert!(has("lttng_ust_mpi"), "MPI events missing");
        assert!(has("lttng_ust_omp"), "OMP events missing");
        assert!(has("lttng_ust_ze"), "layered ZE events missing");
        assert!(has("lttng_ust_profiling"), "profiling events missing");
        std::env::remove_var("THAPI_APP_SCALE");
    }
}
