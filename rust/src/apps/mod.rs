//! Traced workloads: the benchmark suites of the paper's §5.1.
//!
//! * [`hecbench`] — 20 HeCBench-like mini-apps spanning the archetypes of
//!   the real suite (bandwidth-, compute-, launch-, sync- and
//!   polling-bound) across every frontend (ZE, CUDA, HIP-on-ZE, OpenCL,
//!   OpenMP-offload). All kernels execute real PJRT-compiled HLO.
//! * [`spechpc`] — 9 SPEChpc-2021-like MPI + OpenMP-target-offload
//!   benchmarks (505.lbm, 521.miniswp, 534.hpgmgfv, ...) running one rank
//!   per GPU with halo exchanges and allreduces.
//!
//! Workload intensity scales with `THAPI_APP_SCALE` (default 1.0) so the
//! benches can trade runtime for statistical depth.

pub mod hecbench;
pub mod spechpc;

use crate::device::Node;
use std::sync::Arc;

/// A runnable, traced workload.
pub trait Workload: Send + Sync {
    /// Unique name (used in reports and EXPERIMENTS.md).
    fn name(&self) -> &str;
    /// Primary backend label ("ZE", "CUDA", "HIP", "CL", "OMP", "MPI").
    fn backend(&self) -> &'static str;
    /// Execute on a node. Implementations create their frontends, run the
    /// workload to completion and release their resources.
    fn run(&self, node: &Arc<Node>);
}

/// Global intensity multiplier (`THAPI_APP_SCALE`).
pub fn app_scale() -> f64 {
    std::env::var("THAPI_APP_SCALE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .filter(|v| *v > 0.0)
        .unwrap_or(1.0)
}

/// Scale an iteration count (minimum 1).
pub fn scaled(iters: u32) -> u32 {
    ((iters as f64 * app_scale()).round() as u32).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_respects_minimum() {
        assert!(scaled(1) >= 1);
        assert!(scaled(100) >= 1);
    }

    #[test]
    fn suites_have_expected_sizes() {
        assert_eq!(hecbench::suite().len(), 20);
        assert_eq!(spechpc::suite().len(), 9);
        // names unique
        let mut names: Vec<_> = hecbench::suite().iter().map(|a| a.name().to_string()).collect();
        names.extend(spechpc::suite().iter().map(|a| a.name().to_string()));
        let n = names.len();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), n);
    }
}
