//! Self-telemetry: a lock-free metrics registry over the whole pipeline.
//!
//! THAPI's pitch is visibility into every layer of the HPC stack — this
//! module turns that lens on the collector itself. Every pipeline stage
//! (bounded channels, sharded hub, merge, publisher pump, fan-in
//! readers, sinks) bumps atomic counters in one per-hub [`Registry`],
//! so drops, resume gaps, ring evictions and batch efficiency are
//! observable *while the run executes*, not only in the end-of-run
//! summary — and because the end-of-run reports
//! ([`crate::live::LiveStats`], `ServeReport`, `FanInReport`) are thin
//! views over the **same** registry, the two can never disagree.
//!
//! Three exposures, no new dependencies:
//!
//! 1. [`TelemetryServer`] — `--telemetry <addr>` on `iprof serve` /
//!    `attach`: a one-thread HTTP responder serving Prometheus
//!    text-exposition v0.0.4 at `/metrics` (and the same snapshot as
//!    JSON at `/json`).
//! 2. [`JsonSnapshotter`] — `--telemetry-json <path>`: periodic JSON
//!    snapshots in the `bench_support::BenchJson` document shape, for
//!    tests and CI.
//! 3. `iprof health <addr>` — scrape once ([`scrape`]), parse
//!    ([`parse_exposition`]), render a one-screen operator summary
//!    ([`HealthSummary`]) with a strict drop gate.
//!
//! Design rules:
//!
//! * **No hot-path locks.** [`Counter`] / [`Gauge`] are single relaxed
//!   atomics; hot sites hold pre-registered `Arc` handles (per-stream,
//!   per-shard, per-origin), so the labeled-family `RwLock` is touched
//!   only at registration time, never per event.
//! * **Saturating accounting.** Counters pin at `u64::MAX` instead of
//!   wrapping — a telemetry overflow must never report a small number.
//! * **Scrapes are read-only snapshots.** Rendering loads atomics; it
//!   cannot block or perturb the pipeline beyond cache traffic.

pub mod health;
pub mod http;

pub use health::{parse_exposition, HealthSummary, OriginHealth, Sample, SubscriberHealth};
pub use http::{scrape, scrape_path, TelemetryServer};

use crate::bench_support::{js_num, js_str, BenchJson};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// A monotone, saturating, lock-free counter.
///
/// `add` is one relaxed `fetch_add` in the common case; on overflow the
/// value pins at `u64::MAX` instead of wrapping (a wrapped counter
/// would report a *small* loss — the one lie telemetry must never
/// tell).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Increment by `n`, saturating at `u64::MAX`.
    pub fn add(&self, n: u64) {
        if n == 0 {
            return;
        }
        let prev = self.0.fetch_add(n, Ordering::Relaxed);
        if prev.checked_add(n).is_none() {
            // wrapped: pin. Racing adders all pin too, so the value
            // stays at MAX from here on.
            self.0.store(u64::MAX, Ordering::Relaxed);
        }
    }

    /// Monotone absolute update: raise the counter to `v` if `v` is
    /// larger. The mirror primitive for single-writer stats structs
    /// (`PublishStats`, `RemoteStats`) and cumulative wire ledgers
    /// (`Drops` frames report totals, not deltas).
    pub fn store_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A lock-free gauge (set / add / saturating sub).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Set the gauge to `v`.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge by `n` (saturating).
    pub fn add(&self, n: u64) {
        let prev = self.0.fetch_add(n, Ordering::Relaxed);
        if prev.checked_add(n).is_none() {
            self.0.store(u64::MAX, Ordering::Relaxed);
        }
    }

    /// Lower the gauge by `n`, saturating at zero.
    pub fn sub(&self, n: u64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A labeled metric family (`name{label="value"}` series).
///
/// [`Family::with_label`] registers (or finds) a series and hands back
/// an `Arc` handle; hot paths keep the handle and bump it directly, so
/// the internal `RwLock` is only taken at registration and at scrape
/// time — never per event.
#[derive(Debug)]
pub struct Family<M> {
    label: &'static str,
    entries: RwLock<Vec<(String, Arc<M>)>>,
}

/// A family of [`Counter`] series.
pub type CounterFamily = Family<Counter>;
/// A family of [`Gauge`] series.
pub type GaugeFamily = Family<Gauge>;

impl<M: Default> Family<M> {
    fn new(label: &'static str) -> Self {
        Family { label, entries: RwLock::new(Vec::new()) }
    }

    /// The label key this family uses (e.g. `"origin"`).
    pub fn label(&self) -> &'static str {
        self.label
    }

    /// The series for `value`, registering it on first use.
    pub fn with_label(&self, value: &str) -> Arc<M> {
        if let Some((_, m)) = self.entries.read().unwrap().iter().find(|(v, _)| v == value) {
            return m.clone();
        }
        let mut w = self.entries.write().unwrap();
        if let Some((_, m)) = w.iter().find(|(v, _)| v == value) {
            return m.clone(); // lost the registration race
        }
        let m = Arc::new(M::default());
        w.push((value.to_string(), m.clone()));
        m
    }

    /// Snapshot of every series, sorted by label value (deterministic
    /// exposition order).
    pub fn snapshot(&self) -> Vec<(String, Arc<M>)> {
        let mut v: Vec<_> =
            self.entries.read().unwrap().iter().map(|(l, m)| (l.clone(), m.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}

impl CounterFamily {
    /// Sum over every series of the family.
    pub fn sum(&self) -> u64 {
        self.entries.read().unwrap().iter().fold(0u64, |a, (_, c)| a.saturating_add(c.get()))
    }
}

/// The per-hub metrics registry: one atomic field per pipeline meter.
///
/// "Static metric handles": every metric is a named struct field, not a
/// map lookup — an instrumentation site compiles down to one relaxed
/// atomic op. One registry is created per [`crate::live::LiveHub`]
/// (reachable as `hub.telemetry()`), which makes it effectively
/// process-wide for the one-pipeline-per-process `iprof` CLI while
/// keeping tests isolated.
#[derive(Debug)]
pub struct Registry {
    // ── live hub (channels + merge) ────────────────────────────────
    /// Events accepted into hub channels (local + every origin).
    pub live_events_received: Counter,
    /// Events dropped at full channels (the backpressure policy).
    pub live_events_dropped: Counter,
    /// Watermark beacons applied to channels.
    pub live_beacons: Counter,
    /// Events currently queued across all channels.
    pub live_queue_depth: Gauge,
    /// Channels created (local + origin blocks).
    pub live_channels: Gauge,
    /// Per-stream channel drops (`stream` = shared hub index).
    pub channel_dropped: CounterFamily,
    /// Per-stream queue occupancy (`stream` = shared hub index).
    pub channel_depth: GaugeFamily,
    /// Events fed per hub shard (shard 0 = local, i+1 = origin i).
    pub shard_feed: CounterFamily,
    /// Events the merge popped per hub shard.
    pub shard_merged: CounterFamily,
    /// Events released by the k-way merge.
    pub merge_events: Counter,
    /// Total channel-residence nanoseconds of merged events.
    pub merge_latency_ns: Counter,
    /// Merge gate waits (nothing releasable; parked for progress).
    pub merge_gate_waits: Counter,
    /// Periodic sink refresh sweeps.
    pub sink_refresh: Counter,
    /// Total nanoseconds spent inside sink refresh sweeps.
    pub sink_refresh_ns: Counter,

    // ── publisher (`iprof serve`) ──────────────────────────────────
    /// Forward-pump rounds (one `next_forward_batch` per round).
    pub publish_rounds: Counter,
    /// THRL frames written (events, batches, beacons, drops, closes).
    pub publish_frames: Counter,
    /// Events relayed to the wire (batched or per-event).
    pub publish_events: Counter,
    /// Wire bytes written (preamble + every frame, incl. replay).
    pub publish_bytes: Counter,
    /// `EventBatch` frames written (v3 wire only).
    pub publish_batches: Counter,
    /// Dictionary definitions emitted (v3 batch keys, `Def`).
    pub publish_dict_defs: Counter,
    /// Dictionary references emitted (v3 batch keys, `Ref`);
    /// hit rate = refs / (defs + refs).
    pub publish_dict_refs: Counter,
    /// Events replayed from the resume ring to reconnecting viewers.
    pub publish_replayed: Counter,
    /// Events lost to ring eviction and reported as resume gaps.
    pub publish_gap_events: Counter,
    /// Subscriber connections served by this session.
    pub publish_connections: Counter,
    /// Bytes currently held by the replay ring.
    pub ring_bytes: Gauge,
    /// Events evicted from the replay ring (byte budget exceeded).
    pub ring_evicted_events: Counter,

    // ── broadcast subscribers (`iprof serve --subscribers`) ────────
    /// Per-subscriber events encoded for the wire.
    pub subscriber_forwarded_events: CounterFamily,
    /// Per-subscriber events skipped as ring-eviction gaps.
    pub subscriber_lagged_events: CounterFamily,
    /// Per-subscriber demotions (lag budget exceeded under pressure).
    pub subscriber_demotions: CounterFamily,
    /// Per-subscriber connections that ended before `Eos`.
    pub subscriber_disconnects: CounterFamily,

    // ── fan-in readers (`iprof attach`) ────────────────────────────
    /// Per-origin events decoded off the wire.
    pub origin_events: CounterFamily,
    /// Per-origin frames read.
    pub origin_frames: CounterFamily,
    /// Per-origin `EventBatch` frames decoded.
    pub origin_batches: CounterFamily,
    /// Per-origin reconnect attempts that reached a new connection.
    pub origin_reconnects: CounterFamily,
    /// Per-origin events lost to resume gaps (ring outlived outage).
    pub origin_resume_gaps: CounterFamily,
    /// Per-origin publisher-side channel drops (cumulative `Drops`
    /// ledger, confirmed by `Eos`).
    pub origin_remote_dropped: CounterFamily,
    /// Per-origin negotiated THRL wire version (2 or 3).
    pub origin_wire_version: GaugeFamily,
}

/// The label value every per-origin series uses:
/// `<origin index>:<origin label>`. The index prefix keeps series
/// distinct when two publishers announce the same hostname (labels are
/// the Family's identity, unlike the hub's per-shard books), and the
/// hub and the fan-in readers MUST agree on it — both call this.
pub fn origin_series_label(origin: usize, label: &str) -> String {
    format!("{origin}:{label}")
}

/// The label value for a **sub-origin** series: a leaf publisher whose
/// accounting arrived through a relay (`Frame::Origin`), namespaced
/// under the relay connection it came through. `path` is the relay's
/// hierarchical origin id verbatim, so the full label reads e.g.
/// `0:relay1/0:nodeA` — two relays each forwarding an origin labeled
/// `0:nodeA` yield `0:relay1/0:nodeA` and `1:relay2/0:nodeA`, distinct
/// series by construction (the parent prefix is collision-free by the
/// [`origin_series_label`] index rule, recursively).
pub fn sub_origin_series_label(origin: usize, label: &str, path: &str) -> String {
    format!("{}/{path}", origin_series_label(origin, label))
}

impl Registry {
    /// A fresh registry with every meter at zero.
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry {
            live_events_received: Counter::default(),
            live_events_dropped: Counter::default(),
            live_beacons: Counter::default(),
            live_queue_depth: Gauge::default(),
            live_channels: Gauge::default(),
            channel_dropped: Family::new("stream"),
            channel_depth: Family::new("stream"),
            shard_feed: Family::new("shard"),
            shard_merged: Family::new("shard"),
            merge_events: Counter::default(),
            merge_latency_ns: Counter::default(),
            merge_gate_waits: Counter::default(),
            sink_refresh: Counter::default(),
            sink_refresh_ns: Counter::default(),
            publish_rounds: Counter::default(),
            publish_frames: Counter::default(),
            publish_events: Counter::default(),
            publish_bytes: Counter::default(),
            publish_batches: Counter::default(),
            publish_dict_defs: Counter::default(),
            publish_dict_refs: Counter::default(),
            publish_replayed: Counter::default(),
            publish_gap_events: Counter::default(),
            publish_connections: Counter::default(),
            ring_bytes: Gauge::default(),
            ring_evicted_events: Counter::default(),
            subscriber_forwarded_events: Family::new("subscriber"),
            subscriber_lagged_events: Family::new("subscriber"),
            subscriber_demotions: Family::new("subscriber"),
            subscriber_disconnects: Family::new("subscriber"),
            origin_events: Family::new("origin"),
            origin_frames: Family::new("origin"),
            origin_batches: Family::new("origin"),
            origin_reconnects: Family::new("origin"),
            origin_resume_gaps: Family::new("origin"),
            origin_remote_dropped: Family::new("origin"),
            origin_wire_version: Family::new("origin"),
        })
    }

    /// Render the registry as Prometheus text exposition v0.0.4.
    ///
    /// Deterministic: fixed metric order, label values sorted. Families
    /// with no registered series emit their `HELP`/`TYPE` header only
    /// (legal exposition; keeps the metric *catalog* scrape-stable).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        for (name, kind, help, value) in self.scalars() {
            header(&mut out, name, kind, help);
            sample(&mut out, name, &[], &value);
        }
        for (name, kind, help, fam) in self.counter_families() {
            header(&mut out, name, kind, help);
            for (label, c) in fam.snapshot() {
                sample(&mut out, name, &[(fam.label(), &label)], &c.get().to_string());
            }
        }
        for (name, kind, help, fam) in self.gauge_families() {
            header(&mut out, name, kind, help);
            for (label, g) in fam.snapshot() {
                sample(&mut out, name, &[(fam.label(), &label)], &g.get().to_string());
            }
        }
        out
    }

    /// Render the same snapshot as a `BenchJson`-shaped document:
    /// `{"bench": "telemetry", ..., "results": [{"name", "value"}...]}`.
    /// Labeled series carry their exposition-style `{label="v"}` suffix
    /// in `name`.
    pub fn render_json(&self) -> String {
        let mut doc = BenchJson::new("telemetry");
        doc.meta("format", js_str("prometheus-mirror"));
        for (name, _, _, value) in self.scalars() {
            // scalar values are u64 or fixed-point seconds: both parse as f64
            let v: f64 = value.parse().unwrap_or(f64::NAN);
            doc.result(&[("name", js_str(name)), ("value", js_num(v))]);
        }
        for (name, _, _, fam) in self.counter_families() {
            for (label, c) in fam.snapshot() {
                let series = format!("{name}{{{}=\"{}\"}}", fam.label(), escape_label(&label));
                doc.result(&[("name", js_str(&series)), ("value", js_num(c.get() as f64))]);
            }
        }
        for (name, _, _, fam) in self.gauge_families() {
            for (label, g) in fam.snapshot() {
                let series = format!("{name}{{{}=\"{}\"}}", fam.label(), escape_label(&label));
                doc.result(&[("name", js_str(&series)), ("value", js_num(g.get() as f64))]);
            }
        }
        doc.render()
    }

    /// Every unlabeled metric as `(name, type, help, rendered value)`.
    fn scalars(&self) -> Vec<(&'static str, &'static str, &'static str, String)> {
        let secs = |ns: &Counter| format!("{:.9}", ns.get() as f64 / 1e9);
        vec![
            (
                "thapi_live_events_received_total",
                "counter",
                "Events accepted into hub channels (all origins)",
                self.live_events_received.get().to_string(),
            ),
            (
                "thapi_live_events_dropped_total",
                "counter",
                "Events dropped at full channels (never blocks the app)",
                self.live_events_dropped.get().to_string(),
            ),
            (
                "thapi_live_beacons_total",
                "counter",
                "Watermark beacons applied to channels",
                self.live_beacons.get().to_string(),
            ),
            (
                "thapi_live_queue_depth",
                "gauge",
                "Events currently queued across all channels",
                self.live_queue_depth.get().to_string(),
            ),
            (
                "thapi_live_channels",
                "gauge",
                "Channels created (local + origin blocks)",
                self.live_channels.get().to_string(),
            ),
            (
                "thapi_merge_events_total",
                "counter",
                "Events released by the k-way merge",
                self.merge_events.get().to_string(),
            ),
            (
                "thapi_merge_latency_seconds_total",
                "counter",
                "Total channel-residence seconds of merged events",
                secs(&self.merge_latency_ns),
            ),
            (
                "thapi_merge_gate_waits_total",
                "counter",
                "Merge gate waits (parked until push/beacon/close)",
                self.merge_gate_waits.get().to_string(),
            ),
            (
                "thapi_sink_refresh_total",
                "counter",
                "Periodic sink refresh sweeps",
                self.sink_refresh.get().to_string(),
            ),
            (
                "thapi_sink_refresh_seconds_total",
                "counter",
                "Total seconds spent in sink refresh sweeps",
                secs(&self.sink_refresh_ns),
            ),
            (
                "thapi_publish_rounds_total",
                "counter",
                "Publisher forward-pump rounds",
                self.publish_rounds.get().to_string(),
            ),
            (
                "thapi_publish_frames_total",
                "counter",
                "THRL frames written to the wire",
                self.publish_frames.get().to_string(),
            ),
            (
                "thapi_publish_events_total",
                "counter",
                "Events relayed to the wire",
                self.publish_events.get().to_string(),
            ),
            (
                "thapi_publish_bytes_total",
                "counter",
                "Wire bytes written (incl. replay)",
                self.publish_bytes.get().to_string(),
            ),
            (
                "thapi_publish_batches_total",
                "counter",
                "EventBatch frames written (v3 wire)",
                self.publish_batches.get().to_string(),
            ),
            (
                "thapi_publish_dict_defs_total",
                "counter",
                "v3 dictionary definitions emitted",
                self.publish_dict_defs.get().to_string(),
            ),
            (
                "thapi_publish_dict_refs_total",
                "counter",
                "v3 dictionary references emitted (hit rate = refs/(defs+refs))",
                self.publish_dict_refs.get().to_string(),
            ),
            (
                "thapi_publish_replayed_total",
                "counter",
                "Events replayed from the resume ring",
                self.publish_replayed.get().to_string(),
            ),
            (
                "thapi_publish_gap_events_total",
                "counter",
                "Events lost to ring eviction (reported as resume gaps)",
                self.publish_gap_events.get().to_string(),
            ),
            (
                "thapi_publish_connections_total",
                "counter",
                "Subscriber connections served",
                self.publish_connections.get().to_string(),
            ),
            (
                "thapi_ring_bytes",
                "gauge",
                "Bytes currently held by the replay ring",
                self.ring_bytes.get().to_string(),
            ),
            (
                "thapi_ring_evicted_events_total",
                "counter",
                "Events evicted from the replay ring",
                self.ring_evicted_events.get().to_string(),
            ),
        ]
    }

    fn counter_families(&self) -> Vec<(&'static str, &'static str, &'static str, &CounterFamily)> {
        vec![
            (
                "thapi_channel_dropped_total",
                "counter",
                "Per-stream channel drops",
                &self.channel_dropped,
            ),
            ("thapi_shard_feed_total", "counter", "Events fed per hub shard", &self.shard_feed),
            (
                "thapi_shard_merged_total",
                "counter",
                "Events popped by the merge per hub shard",
                &self.shard_merged,
            ),
            (
                "thapi_origin_events_total",
                "counter",
                "Per-origin events decoded off the wire",
                &self.origin_events,
            ),
            ("thapi_origin_frames_total", "counter", "Per-origin frames read", &self.origin_frames),
            (
                "thapi_origin_batches_total",
                "counter",
                "Per-origin EventBatch frames decoded",
                &self.origin_batches,
            ),
            (
                "thapi_origin_reconnects_total",
                "counter",
                "Per-origin reconnect attempts that produced a connection",
                &self.origin_reconnects,
            ),
            (
                "thapi_origin_resume_gap_events_total",
                "counter",
                "Per-origin events lost to resume gaps",
                &self.origin_resume_gaps,
            ),
            (
                "thapi_origin_remote_dropped_total",
                "counter",
                "Per-origin publisher-side channel drops (cumulative ledger)",
                &self.origin_remote_dropped,
            ),
            (
                "thapi_subscriber_forwarded_events_total",
                "counter",
                "Per-subscriber events encoded for the wire",
                &self.subscriber_forwarded_events,
            ),
            (
                "thapi_subscriber_lagged_events_total",
                "counter",
                "Per-subscriber events skipped as ring-eviction gaps",
                &self.subscriber_lagged_events,
            ),
            (
                "thapi_subscriber_demotions_total",
                "counter",
                "Per-subscriber lag-budget demotions",
                &self.subscriber_demotions,
            ),
            (
                "thapi_subscriber_disconnects_total",
                "counter",
                "Per-subscriber connections ended before Eos",
                &self.subscriber_disconnects,
            ),
        ]
    }

    fn gauge_families(&self) -> Vec<(&'static str, &'static str, &'static str, &GaugeFamily)> {
        vec![
            (
                "thapi_channel_queue_depth",
                "gauge",
                "Per-stream channel occupancy",
                &self.channel_depth,
            ),
            (
                "thapi_origin_wire_version",
                "gauge",
                "Per-origin negotiated THRL wire version",
                &self.origin_wire_version,
            ),
        ]
    }
}

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

fn sample(out: &mut String, name: &str, labels: &[(&str, &str)], value: &str) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(&escape_label(v));
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    out.push_str(value);
    out.push('\n');
}

/// Escape a label value per the exposition format: `\` `"` and newline.
fn escape_label(v: &str) -> String {
    let mut s = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => s.push_str("\\\\"),
            '"' => s.push_str("\\\""),
            '\n' => s.push_str("\\n"),
            _ => s.push(ch),
        }
    }
    s
}

/// Background JSON snapshot writer (`--telemetry-json <path>`).
///
/// Writes the registry's [`Registry::render_json`] document to `path`
/// immediately, then every `period`, then once more at shutdown — so
/// even a run shorter than one period leaves a final, complete
/// snapshot behind (what tests and CI consume).
pub struct JsonSnapshotter {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl JsonSnapshotter {
    /// Start the writer thread. The first snapshot is written (and its
    /// errors reported) before this returns; later write failures are
    /// silently retried next period — telemetry must not kill the run.
    pub fn start(
        path: PathBuf,
        registry: Arc<Registry>,
        period: Duration,
    ) -> std::io::Result<JsonSnapshotter> {
        std::fs::write(&path, registry.render_json())?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let handle = std::thread::Builder::new().name("thapi-telemetry-json".into()).spawn(
            move || {
                let tick = Duration::from_millis(25).min(period);
                let mut elapsed = Duration::ZERO;
                while !flag.load(Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    elapsed += tick;
                    if elapsed >= period {
                        elapsed = Duration::ZERO;
                        let _ = std::fs::write(&path, registry.render_json());
                    }
                }
                // final snapshot: the numbers a finished run settles on
                let _ = std::fs::write(&path, registry.render_json());
            },
        )?;
        Ok(JsonSnapshotter { stop, handle: Some(handle) })
    }

    /// Stop the writer and flush the final snapshot.
    pub fn finish(mut self) {
        self.stop_join();
    }

    fn stop_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for JsonSnapshotter {
    fn drop(&mut self) {
        self.stop_join();
    }
}

/// CLI-facing exposure selection (`--telemetry`, `--telemetry-json`):
/// which exposures to run for the duration of one serve / attach.
/// `Default` exposes nothing — the registry still accumulates, it just
/// is not served anywhere.
#[derive(Debug, Clone, Default)]
pub struct TelemetryOptions {
    /// Bind a [`TelemetryServer`] here (`--telemetry <addr>`).
    pub addr: Option<String>,
    /// Write periodic JSON snapshots here (`--telemetry-json <path>`).
    pub json_path: Option<PathBuf>,
    /// JSON snapshot period (default 1 s).
    pub json_period: Option<Duration>,
}

impl TelemetryOptions {
    /// Anything to expose at all?
    pub fn is_enabled(&self) -> bool {
        self.addr.is_some() || self.json_path.is_some()
    }
}

/// Everything [`TelemetryOptions`] asked for, running: the HTTP scrape
/// endpoint and/or the JSON snapshot writer over one pipeline's
/// registry. Dropping stops both (the snapshotter flushes one final
/// document first), so error paths clean up without ceremony.
pub struct TelemetryExposure {
    server: Option<TelemetryServer>,
    json: Option<JsonSnapshotter>,
}

impl TelemetryExposure {
    /// Start whatever `opts` enables over `registry`. A bind or write
    /// failure is a hard error: the operator explicitly asked for this
    /// exposure, and running blind while they believe they are watching
    /// would be worse than failing the launch.
    pub fn start(
        opts: &TelemetryOptions,
        registry: &Arc<Registry>,
    ) -> std::io::Result<TelemetryExposure> {
        let server = match &opts.addr {
            Some(addr) => Some(TelemetryServer::bind(addr, registry.clone())?),
            None => None,
        };
        let json = match &opts.json_path {
            Some(path) => Some(JsonSnapshotter::start(
                path.clone(),
                registry.clone(),
                opts.json_period.unwrap_or(Duration::from_secs(1)),
            )?),
            None => None,
        };
        Ok(TelemetryExposure { server, json })
    }

    /// The bound scrape address, if an HTTP endpoint is running (with
    /// `--telemetry 127.0.0.1:0` the OS picks the port; this is it).
    pub fn local_addr(&self) -> Option<std::net::SocketAddr> {
        self.server.as_ref().map(|s| s.local_addr())
    }

    /// Stop the endpoint and flush the final JSON snapshot. Call after
    /// the pipeline's threads have joined so the last document carries
    /// the settled end-of-run numbers.
    pub fn finish(self) {
        if let Some(s) = self.server {
            s.shutdown();
        }
        if let Some(j) = self.json {
            j.finish();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_saturates_instead_of_wrapping() {
        let c = Counter::default();
        c.add(u64::MAX - 1);
        c.add(10);
        assert_eq!(c.get(), u64::MAX);
        c.inc();
        assert_eq!(c.get(), u64::MAX);
    }

    #[test]
    fn counter_store_max_is_monotone() {
        let c = Counter::default();
        c.store_max(7);
        c.store_max(3); // a stale mirror can never move a ledger backwards
        assert_eq!(c.get(), 7);
        c.store_max(9);
        assert_eq!(c.get(), 9);
    }

    #[test]
    fn gauge_sub_saturates_at_zero() {
        let g = Gauge::default();
        g.add(5);
        g.sub(9);
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn family_handles_are_shared_and_sorted() {
        let f: CounterFamily = Family::new("origin");
        let a = f.with_label("nodeB");
        let b = f.with_label("nodeA");
        let a2 = f.with_label("nodeB");
        a.add(2);
        a2.add(3);
        b.inc();
        let snap = f.snapshot();
        assert_eq!(
            snap.iter().map(|(l, c)| (l.as_str(), c.get())).collect::<Vec<_>>(),
            vec![("nodeA", 1), ("nodeB", 5)]
        );
        assert_eq!(f.sum(), 6);
    }

    #[test]
    fn exposition_renders_headers_series_and_escapes() {
        let reg = Registry::new();
        reg.live_events_received.add(42);
        reg.origin_events.with_label("host\"1\"").add(7);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE thapi_live_events_received_total counter"));
        assert!(text.contains("thapi_live_events_received_total 42\n"));
        assert!(text.contains("thapi_origin_events_total{origin=\"host\\\"1\\\"\"} 7\n"));
        // seconds metrics render as fixed-point floats
        assert!(text.contains("thapi_merge_latency_seconds_total 0.000000000\n"));
        // every line is a header or a sample: the parser must accept all of it
        let samples = parse_exposition(&text).expect("own exposition must parse");
        assert!(samples.iter().any(|s| s.name == "thapi_live_events_received_total"
            && s.value == 42.0));
    }

    #[test]
    fn json_snapshot_is_benchjson_shaped() {
        let reg = Registry::new();
        reg.merge_events.add(5);
        let doc = reg.render_json();
        assert!(doc.contains("\"bench\": \"telemetry\""));
        assert!(doc.contains("\"name\": \"thapi_merge_events_total\""));
        assert!(doc.contains("\"results\": ["));
    }

    #[test]
    fn json_snapshotter_writes_initial_and_final() {
        let dir = std::env::temp_dir().join(format!("thapi-tele-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        let reg = Registry::new();
        let w =
            JsonSnapshotter::start(path.clone(), reg.clone(), Duration::from_secs(3600)).unwrap();
        assert!(path.exists(), "initial snapshot must be written synchronously");
        reg.live_events_received.add(9);
        w.finish();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(
            text.contains("\"name\": \"thapi_live_events_received_total\""),
            "final snapshot must exist: {text}"
        );
        // the final write happens after the counter bump above
        let samples: Vec<_> = text.lines().filter(|l| l.contains("live_events_received")).collect();
        assert_eq!(samples.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
