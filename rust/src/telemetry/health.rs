//! `iprof health`: scrape a telemetry endpoint once and summarize it
//! for an operator.
//!
//! The exposition parser here is the *consumer-side* twin of
//! [`super::Registry::render_prometheus`] — the CI smoke and the golden
//! tests parse the endpoint's output back through it, so a rendering
//! regression cannot land silently. [`HealthSummary`] condenses the
//! sample set into the one screen an operator scans during an incident:
//! pipeline totals, per-origin ledgers, and a strict loss gate
//! ([`HealthSummary::known_loss`]) aligned with `--live-strict`.

use crate::bench_support::Table;

/// One parsed exposition sample: `name{labels} value`.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Metric name (e.g. `thapi_live_events_dropped_total`).
    pub name: String,
    /// Label pairs in exposition order.
    pub labels: Vec<(String, String)>,
    /// Sample value.
    pub value: f64,
}

impl Sample {
    /// The value of label `key`, if present.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// Parse Prometheus text exposition v0.0.4 into samples.
///
/// Accepts exactly what the registry renders (and what any conforming
/// exporter emits): `# HELP`/`# TYPE`/comment lines are skipped, sample
/// lines are `name[{k="v",...}] value [timestamp]`. Returns a
/// description of the first malformed line on failure.
pub fn parse_exposition(text: &str) -> Result<Vec<Sample>, String> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push(parse_sample(line).map_err(|e| format!("line {}: {e}: {line}", lineno + 1))?);
    }
    Ok(out)
}

fn parse_sample(line: &str) -> Result<Sample, String> {
    let (name_part, rest) = match line.find('{') {
        Some(brace) => {
            let close = line.rfind('}').ok_or("unterminated label set")?;
            if close < brace {
                return Err("mismatched braces".into());
            }
            (&line[..brace], Some((&line[brace + 1..close], &line[close + 1..])))
        }
        None => (
            line.split_whitespace().next().ok_or("empty sample")?,
            None,
        ),
    };
    let name = name_part.trim();
    if name.is_empty()
        || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    {
        return Err(format!("invalid metric name {name:?}"));
    }
    let (labels, value_part) = match rest {
        Some((labels_text, tail)) => (parse_labels(labels_text)?, tail),
        None => (Vec::new(), &line[name_part.len()..]),
    };
    let value_text =
        value_part.split_whitespace().next().ok_or("missing value")?;
    let value: f64 = value_text
        .parse()
        .map_err(|_| format!("unparseable value {value_text:?}"))?;
    Ok(Sample { name: name.to_string(), labels, value })
}

fn parse_labels(text: &str) -> Result<Vec<(String, String)>, String> {
    let mut labels = Vec::new();
    let mut chars = text.chars().peekable();
    loop {
        while matches!(chars.peek(), Some(',') | Some(' ')) {
            chars.next();
        }
        if chars.peek().is_none() {
            return Ok(labels);
        }
        let mut key = String::new();
        for c in chars.by_ref() {
            if c == '=' {
                break;
            }
            key.push(c);
        }
        if chars.next() != Some('"') {
            return Err(format!("label {key:?}: value must be quoted"));
        }
        let mut value = String::new();
        let mut closed = false;
        while let Some(c) = chars.next() {
            match c {
                '\\' => match chars.next() {
                    Some('\\') => value.push('\\'),
                    Some('"') => value.push('"'),
                    Some('n') => value.push('\n'),
                    other => return Err(format!("bad escape \\{other:?}")),
                },
                '"' => {
                    closed = true;
                    break;
                }
                c => value.push(c),
            }
        }
        if !closed {
            return Err("unterminated label value".into());
        }
        labels.push((key.trim().to_string(), value));
    }
}

/// Sum of every sample with `name` (0 when the metric is absent).
fn total(samples: &[Sample], name: &str) -> u64 {
    samples.iter().filter(|s| s.name == name).map(|s| s.value.max(0.0) as u64).sum()
}

/// One origin's row in the health view.
#[derive(Debug, Clone, Default)]
pub struct OriginHealth {
    /// Origin label (the publisher's address/hostname).
    pub origin: String,
    /// Negotiated THRL wire version (0 = not yet negotiated).
    pub wire_version: u64,
    /// Events decoded off this origin's wire.
    pub events: u64,
    /// `EventBatch` frames decoded.
    pub batches: u64,
    /// Reconnect attempts that produced a connection.
    pub reconnects: u64,
    /// Events lost to resume gaps.
    pub resume_gaps: u64,
    /// Publisher-side channel drops (cumulative ledger).
    pub remote_dropped: u64,
}

/// One broadcast subscriber's row in the health view
/// (`iprof serve --subscribers`).
#[derive(Debug, Clone, Default)]
pub struct SubscriberHealth {
    /// Subscriber id (registration order on the serving publisher).
    pub subscriber: String,
    /// Events encoded for this subscriber's wire.
    pub forwarded: u64,
    /// Events skipped as ring-eviction gaps on this connection.
    pub lagged: u64,
    /// Lag-budget demotions (0 or 1; demotion is sticky).
    pub demoted: u64,
    /// Connections that ended before `Eos`.
    pub disconnects: u64,
}

/// The one-screen operator summary `iprof health` renders.
#[derive(Debug, Clone, Default)]
pub struct HealthSummary {
    /// Events accepted into the endpoint's hub.
    pub received: u64,
    /// Events the merge released to the sinks.
    pub merged: u64,
    /// Viewer-side channel drops.
    pub dropped: u64,
    /// Events still queued (scrape-time lag).
    pub queue_depth: u64,
    /// Mean channel-residence seconds per merged event.
    pub mean_latency_s: f64,
    /// Publisher pump rounds (nonzero only on a `serve` endpoint).
    pub publish_rounds: u64,
    /// Events relayed to the wire by a `serve` endpoint.
    pub publish_events: u64,
    /// Wire bytes written by a `serve` endpoint.
    pub publish_bytes: u64,
    /// Events evicted from the replay ring.
    pub ring_evicted: u64,
    /// Per-origin rows (nonempty only on an `attach` endpoint).
    pub origins: Vec<OriginHealth>,
    /// Per-subscriber rows (nonempty only on a broadcast `serve`).
    pub subscribers: Vec<SubscriberHealth>,
}

impl HealthSummary {
    /// Condense a parsed scrape into the operator view.
    pub fn from_samples(samples: &[Sample]) -> HealthSummary {
        let merged = total(samples, "thapi_merge_events_total");
        let latency_s: f64 = samples
            .iter()
            .filter(|s| s.name == "thapi_merge_latency_seconds_total")
            .map(|s| s.value)
            .sum();
        let mut origins: Vec<OriginHealth> = Vec::new();
        let mut row = |origin: &str| -> usize {
            match origins.iter().position(|o| o.origin == origin) {
                Some(i) => i,
                None => {
                    origins.push(OriginHealth {
                        origin: origin.to_string(),
                        ..OriginHealth::default()
                    });
                    origins.len() - 1
                }
            }
        };
        for s in samples {
            let Some(origin) = s.label("origin") else { continue };
            let i = row(origin);
            let v = s.value.max(0.0) as u64;
            match s.name.as_str() {
                "thapi_origin_events_total" => origins[i].events = v,
                "thapi_origin_batches_total" => origins[i].batches = v,
                "thapi_origin_reconnects_total" => origins[i].reconnects = v,
                "thapi_origin_resume_gap_events_total" => origins[i].resume_gaps = v,
                "thapi_origin_remote_dropped_total" => origins[i].remote_dropped = v,
                "thapi_origin_wire_version" => origins[i].wire_version = v,
                _ => {}
            }
        }
        origins.sort_by(|a, b| a.origin.cmp(&b.origin));
        let mut subscribers: Vec<SubscriberHealth> = Vec::new();
        let mut sub_row = |id: &str| -> usize {
            match subscribers.iter().position(|s| s.subscriber == id) {
                Some(i) => i,
                None => {
                    subscribers.push(SubscriberHealth {
                        subscriber: id.to_string(),
                        ..SubscriberHealth::default()
                    });
                    subscribers.len() - 1
                }
            }
        };
        for s in samples {
            let Some(id) = s.label("subscriber") else { continue };
            let i = sub_row(id);
            let v = s.value.max(0.0) as u64;
            match s.name.as_str() {
                "thapi_subscriber_forwarded_events_total" => subscribers[i].forwarded = v,
                "thapi_subscriber_lagged_events_total" => subscribers[i].lagged = v,
                "thapi_subscriber_demotions_total" => subscribers[i].demoted = v,
                "thapi_subscriber_disconnects_total" => subscribers[i].disconnects = v,
                _ => {}
            }
        }
        // ids are registration indices: sort numerically where possible
        subscribers.sort_by_key(|s| (s.subscriber.parse::<u64>().ok(), s.subscriber.clone()));
        HealthSummary {
            received: total(samples, "thapi_live_events_received_total"),
            merged,
            dropped: total(samples, "thapi_live_events_dropped_total"),
            queue_depth: total(samples, "thapi_live_queue_depth"),
            mean_latency_s: if merged == 0 { 0.0 } else { latency_s / merged as f64 },
            publish_rounds: total(samples, "thapi_publish_rounds_total"),
            publish_events: total(samples, "thapi_publish_events_total"),
            publish_bytes: total(samples, "thapi_publish_bytes_total"),
            ring_evicted: total(samples, "thapi_ring_evicted_events_total"),
            origins: origins.into_iter().filter(|o| o.origin != "local").collect(),
            subscribers,
        }
    }

    /// Everything this endpoint *knows* it lost: viewer-side channel
    /// drops, plus per-origin resume gaps, plus publisher-side drops.
    ///
    /// Gap events never reach a channel (they were evicted publisher
    /// side), and the publisher-side ledger counts pre-wire drops — the
    /// three terms are disjoint by construction, so the sum neither
    /// double-counts nor hides loss. The per-origin term is the ledger
    /// branch of `FanInReport::known_dropped()` (gaps + wire drops);
    /// the exposition carries no publisher Eos sample, so the opaque
    /// self-reported total that `known_dropped()` maxes against is not
    /// consulted here. Per-subscriber `lagged` counts are *not* loss at
    /// this endpoint: a lagged broadcast subscriber books the same span
    /// as resume gaps on its own attach side, where strict mode already
    /// gates it.
    pub fn known_loss(&self) -> u64 {
        let origin_loss = self.origins.iter().fold(0u64, |a, o| {
            a.saturating_add(o.resume_gaps).saturating_add(o.remote_dropped)
        });
        self.dropped.saturating_add(origin_loss)
    }

    /// Render the one-screen summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("pipeline\n");
        let mut t = Table::new(&["received", "merged", "dropped", "queued", "mean latency"]);
        t.row(&[
            self.received.to_string(),
            self.merged.to_string(),
            self.dropped.to_string(),
            self.queue_depth.to_string(),
            format!("{:.3} ms", self.mean_latency_s * 1e3),
        ]);
        out.push_str(&t.render());
        if self.publish_rounds > 0 {
            out.push_str("\npublisher\n");
            let mut t = Table::new(&["rounds", "events", "wire bytes", "ring evicted"]);
            t.row(&[
                self.publish_rounds.to_string(),
                self.publish_events.to_string(),
                self.publish_bytes.to_string(),
                self.ring_evicted.to_string(),
            ]);
            out.push_str(&t.render());
        }
        if !self.subscribers.is_empty() {
            out.push_str("\nsubscribers\n");
            let mut t =
                Table::new(&["subscriber", "forwarded", "lagged", "demoted", "disconnects"]);
            for s in &self.subscribers {
                t.row(&[
                    s.subscriber.clone(),
                    s.forwarded.to_string(),
                    s.lagged.to_string(),
                    s.demoted.to_string(),
                    s.disconnects.to_string(),
                ]);
            }
            out.push_str(&t.render());
        }
        if !self.origins.is_empty() {
            out.push_str("\norigins\n");
            let mut t = Table::new(&[
                "origin",
                "wire",
                "events",
                "batches",
                "reconnects",
                "resume gaps",
                "remote dropped",
            ]);
            for o in &self.origins {
                t.row(&[
                    o.origin.clone(),
                    if o.wire_version == 0 { "?".into() } else { format!("v{}", o.wire_version) },
                    o.events.to_string(),
                    o.batches.to_string(),
                    o.reconnects.to_string(),
                    o.resume_gaps.to_string(),
                    o.remote_dropped.to_string(),
                ]);
            }
            out.push_str(&t.render());
        }
        out.push_str(&format!("\nknown loss: {} event(s)\n", self.known_loss()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_plain_labeled_and_escaped_samples() {
        let text = "# HELP x y\n# TYPE x counter\nx 3\n\
                    y{origin=\"node:7007\"} 4\n\
                    z{a=\"q\\\"o\\\"t\",b=\"n\\nl\"} 1.5 1700000000\n";
        let s = parse_exposition(text).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!((s[0].name.as_str(), s[0].value), ("x", 3.0));
        assert_eq!(s[1].label("origin"), Some("node:7007"));
        assert_eq!(s[2].label("a"), Some("q\"o\"t"));
        assert_eq!(s[2].label("b"), Some("n\nl"));
        assert_eq!(s[2].value, 1.5);
    }

    #[test]
    fn malformed_lines_are_reported_not_panicked() {
        for bad in ["x{unterminated 3", "x{k=unquoted} 3", "x{k=\"v\"}", "{k=\"v\"} 3", "x notanum"]
        {
            assert!(parse_exposition(bad).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn summary_totals_and_strict_loss() {
        let text = "thapi_live_events_received_total 100\n\
                    thapi_live_events_dropped_total 3\n\
                    thapi_merge_events_total 97\n\
                    thapi_live_queue_depth 0\n\
                    thapi_origin_events_total{origin=\"a:1\"} 60\n\
                    thapi_origin_resume_gap_events_total{origin=\"a:1\"} 2\n\
                    thapi_origin_remote_dropped_total{origin=\"a:1\"} 5\n\
                    thapi_origin_wire_version{origin=\"a:1\"} 3\n";
        let samples = parse_exposition(text).unwrap();
        let h = HealthSummary::from_samples(&samples);
        assert_eq!(h.received, 100);
        assert_eq!(h.dropped, 3);
        assert_eq!(h.origins.len(), 1);
        assert_eq!(h.origins[0].wire_version, 3);
        // 3 viewer drops + 2 gap events + 5 publisher-side drops
        assert_eq!(h.known_loss(), 10);
        let screen = h.render();
        assert!(screen.contains("a:1"));
        assert!(screen.contains("known loss: 10"));
    }

    #[test]
    fn subscriber_rows_render_without_entering_known_loss() {
        let text = "thapi_live_events_received_total 20\n\
                    thapi_merge_events_total 20\n\
                    thapi_subscriber_forwarded_events_total{subscriber=\"0\"} 20\n\
                    thapi_subscriber_forwarded_events_total{subscriber=\"10\"} 13\n\
                    thapi_subscriber_forwarded_events_total{subscriber=\"2\"} 13\n\
                    thapi_subscriber_lagged_events_total{subscriber=\"2\"} 7\n\
                    thapi_subscriber_demotions_total{subscriber=\"2\"} 1\n\
                    thapi_subscriber_disconnects_total{subscriber=\"10\"} 1\n";
        let h = HealthSummary::from_samples(&parse_exposition(text).unwrap());
        // numeric sort, not lexical: 0, 2, 10
        assert_eq!(
            h.subscribers.iter().map(|s| s.subscriber.as_str()).collect::<Vec<_>>(),
            vec!["0", "2", "10"]
        );
        assert_eq!((h.subscribers[1].lagged, h.subscribers[1].demoted), (7, 1));
        assert_eq!(h.subscribers[2].disconnects, 1);
        // lagged events are the subscriber's view loss, not pipeline loss
        assert_eq!(h.known_loss(), 0);
        let screen = h.render();
        assert!(screen.contains("subscribers"));
        assert!(screen.contains("demoted"));
    }
}
