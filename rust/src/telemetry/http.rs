//! The built-in scrape endpoint: a deliberately minimal HTTP/1.0
//! responder (one thread, no dependencies, read-only snapshots) plus
//! the matching one-shot client used by `iprof health` and the tests.
//!
//! This is not a web server. It answers exactly one request shape —
//! `GET <path> …` — with a complete response and closes the
//! connection. `/json` (any path starting with it) returns the
//! [`Registry::render_json`] document; every other path returns
//! Prometheus text exposition v0.0.4, so `/metrics` works and so does
//! a bare `GET /`. Malformed requests get a `400` and a closed
//! connection; nothing an external client sends can perturb the
//! pipeline beyond one bounded read.

use super::Registry;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Cap on the request head we are willing to buffer.
const MAX_REQUEST: usize = 4096;

/// The `--telemetry <addr>` scrape endpoint.
///
/// One accept-loop thread serving read-only registry snapshots;
/// [`TelemetryServer::shutdown`] (or drop) stops it deterministically.
pub struct TelemetryServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl TelemetryServer {
    /// Bind `addr` (e.g. `127.0.0.1:9100`, port 0 for ephemeral) and
    /// start serving `registry` snapshots.
    pub fn bind(addr: &str, registry: Arc<Registry>) -> io::Result<TelemetryServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let handle = std::thread::Builder::new().name("thapi-telemetry".into()).spawn(move || {
            for conn in listener.incoming() {
                if flag.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(mut conn) = conn else { continue };
                // per-connection errors (slow loris, reset) only end
                // that connection — the endpoint itself stays up
                let _ = serve_one(&mut conn, &registry);
            }
        })?;
        Ok(TelemetryServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the thread.
    pub fn shutdown(mut self) {
        self.stop_join();
    }

    fn stop_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // unblock the accept() with a throwaway connection to ourselves
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(500));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TelemetryServer {
    fn drop(&mut self) {
        self.stop_join();
    }
}

/// Answer one request on `conn` and close it.
fn serve_one(conn: &mut TcpStream, registry: &Registry) -> io::Result<()> {
    conn.set_read_timeout(Some(Duration::from_secs(2)))?;
    conn.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut head = Vec::with_capacity(256);
    let mut buf = [0u8; 512];
    // read until end-of-head; the shutdown self-connect sends nothing,
    // so EOF / timeout with an empty head is a silent no-op
    while !head.windows(4).any(|w| w == b"\r\n\r\n") {
        if head.len() >= MAX_REQUEST {
            return respond(conn, 400, "text/plain; charset=utf-8", "request too large\n");
        }
        match conn.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&buf[..n]),
            Err(_) => break,
        }
    }
    if head.is_empty() {
        return Ok(());
    }
    let text = String::from_utf8_lossy(&head);
    let mut first = text.lines().next().unwrap_or("").split_whitespace();
    let (method, path) = (first.next().unwrap_or(""), first.next().unwrap_or(""));
    if method != "GET" {
        return respond(conn, 400, "text/plain; charset=utf-8", "only GET is served\n");
    }
    if path.starts_with("/json") {
        respond(conn, 200, "application/json; charset=utf-8", &registry.render_json())
    } else {
        // /metrics and everything else: the exposition snapshot
        respond(
            conn,
            200,
            "text/plain; version=0.0.4; charset=utf-8",
            &registry.render_prometheus(),
        )
    }
}

fn respond(conn: &mut TcpStream, status: u16, ctype: &str, body: &str) -> io::Result<()> {
    let reason = if status == 200 { "OK" } else { "Bad Request" };
    let head = format!(
        "HTTP/1.0 {status} {reason}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    conn.write_all(head.as_bytes())?;
    conn.write_all(body.as_bytes())?;
    conn.flush()
}

/// Scrape `/metrics` from a telemetry endpoint; the body on HTTP 200.
pub fn scrape(addr: &str) -> io::Result<String> {
    scrape_path(addr, "/metrics")
}

/// Scrape an arbitrary path (e.g. `/json`) from a telemetry endpoint.
pub fn scrape_path(addr: &str, path: &str) -> io::Result<String> {
    let mut conn = TcpStream::connect(addr)?;
    conn.set_read_timeout(Some(Duration::from_secs(5)))?;
    conn.set_write_timeout(Some(Duration::from_secs(5)))?;
    conn.write_all(format!("GET {path} HTTP/1.0\r\nHost: thapi\r\n\r\n").as_bytes())?;
    let mut response = String::new();
    conn.read_to_string(&mut response)?;
    let (head, body) = response
        .split_once("\r\n\r\n")
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "no HTTP header terminator"))?;
    let status = head.lines().next().unwrap_or("");
    if !status.contains(" 200 ") {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("telemetry endpoint answered: {status}"),
        ));
    }
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_scrape_shutdown_roundtrip() {
        let reg = Registry::new();
        reg.live_events_received.add(123);
        let srv = TelemetryServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        let addr = srv.local_addr().to_string();

        let body = scrape(&addr).unwrap();
        assert!(body.contains("thapi_live_events_received_total 123\n"));

        // counters keep moving between scrapes: snapshots are live reads
        reg.live_events_received.add(1);
        let body2 = scrape(&addr).unwrap();
        assert!(body2.contains("thapi_live_events_received_total 124\n"));

        let json = scrape_path(&addr, "/json").unwrap();
        assert!(json.contains("\"bench\": \"telemetry\""));

        srv.shutdown();
        assert!(
            TcpStream::connect_timeout(
                &addr.parse().unwrap(),
                Duration::from_millis(200)
            )
            .map(|mut c| {
                // a lingering listener backlog entry may still accept;
                // a served response would mean the thread survived
                let _ = c.write_all(b"GET /metrics HTTP/1.0\r\n\r\n");
                let mut s = String::new();
                let _ = c.set_read_timeout(Some(Duration::from_millis(300)));
                let _ = c.read_to_string(&mut s);
                s.is_empty()
            })
            .unwrap_or(true),
            "endpoint must stop serving after shutdown"
        );
    }

    #[test]
    fn non_get_requests_are_rejected() {
        let reg = Registry::new();
        let srv = TelemetryServer::bind("127.0.0.1:0", reg).unwrap();
        let mut conn = TcpStream::connect(srv.local_addr()).unwrap();
        conn.write_all(b"POST /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut s = String::new();
        conn.read_to_string(&mut s).unwrap();
        assert!(s.starts_with("HTTP/1.0 400"), "got: {s}");
        srv.shutdown();
    }
}
