//! The LTTng-UST substitute: low-overhead userspace tracing substrate.
//!
//! Mirrors the properties the paper relies on (§3.1):
//!
//! * **lockless per-thread ring buffers** — each traced thread owns an SPSC
//!   byte ring ([`ringbuf`]); the emit path takes no locks and performs no
//!   allocation.
//! * **discard mode** — if a buffer is full the event is dropped (counted),
//!   never blocking the application.
//! * **selective tracing** — sessions ([`session`]) enable/disable event
//!   classes via an atomic bitmap; a disabled class costs two loads.
//! * **binary trace format** — BTF ([`btf`]), our CTF stand-in: a text
//!   metadata stream generated from the trace model plus per-thread binary
//!   event streams, parsed offline by the [`crate::analysis`] plugins.
//!
//! The global entry point is [`emit`]; interception frontends call it with a
//! pre-resolved [`EventClass`](crate::model::EventClass) and a closure that
//! encodes the payload fields.

pub mod btf;
pub mod clock;
pub mod consumer;
pub mod encoder;
pub mod ringbuf;
pub mod session;

pub use clock::now_ns;
pub use encoder::Encoder;
pub use session::{
    emit, install_session, register_thread, session_stats, set_thread_rank, uninstall_session,
    Session, SessionConfig, SessionStats, SinkKind, TracingMode,
};
