//! Lockless SPSC byte ring buffer with LTTng-style *discard* semantics.
//!
//! One producer (the traced thread) and one consumer (the background
//! [`consumer`](crate::tracer::consumer) thread). Records are written
//! contiguously; a record that would straddle the physical end of the
//! buffer is preceded by a padding marker so the consumer can skip to the
//! wrap point. If there is not enough free space the record is **dropped
//! and counted** — the tracer never blocks the application (paper §3.1).
//!
//! Record wire layout (4-byte aligned):
//! `[u32 total_len][u32 class_id][u64 timestamp][payload...]`
//! A `total_len` of [`PAD_MARKER`] means "skip to the end of the buffer".

use crossbeam_utils::CachePadded;
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// `total_len` sentinel marking wrap padding.
pub const PAD_MARKER: u32 = u32::MAX;

/// Fixed per-record header: total_len + class_id + timestamp.
pub const RECORD_HEADER: usize = 4 + 4 + 8;

/// Lockless single-producer single-consumer byte ring.
pub struct RingBuf {
    buf: UnsafeCell<Box<[u8]>>,
    cap: usize,
    /// Producer cursor: total bytes ever written (not wrapped).
    head: CachePadded<AtomicU64>,
    /// Consumer cursor: total bytes ever consumed.
    tail: CachePadded<AtomicU64>,
    /// Events dropped because the buffer was full.
    dropped: AtomicU64,
    /// Events successfully written.
    written: AtomicU64,
}

// SAFETY: the byte region is only mutated by the single producer between
// `tail..head` reservations, and only read by the single consumer below
// `head` (Acquire). Cursor atomics order the accesses.
unsafe impl Send for RingBuf {}
unsafe impl Sync for RingBuf {}

impl RingBuf {
    /// Create a ring with capacity `cap` bytes (rounded up to a power of 2,
    /// minimum 4 KiB).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(4096).next_power_of_two();
        RingBuf {
            buf: UnsafeCell::new(vec![0u8; cap].into_boxed_slice()),
            cap,
            head: CachePadded::new(AtomicU64::new(0)),
            tail: CachePadded::new(AtomicU64::new(0)),
            dropped: AtomicU64::new(0),
            written: AtomicU64::new(0),
        }
    }

    /// Buffer capacity in bytes.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Events dropped so far (discard mode).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Events written so far.
    pub fn written(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }

    #[inline]
    fn slot(&self, pos: u64) -> usize {
        (pos as usize) & (self.cap - 1)
    }

    /// Producer: try to append one record. `class_id`, `ts` fill the record
    /// header; `payload` is the encoded field data. Returns `false` (and
    /// counts a drop) if there is not enough space.
    ///
    /// # Safety contract
    /// Must only be called from the single producer thread for this ring.
    #[inline]
    pub fn try_write(&self, class_id: u32, ts: u64, payload: &[u8]) -> bool {
        let len = RECORD_HEADER + payload.len();
        let len = (len + 3) & !3; // keep 4-byte alignment
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        let free = self.cap - (head - tail) as usize;

        let off = self.slot(head);
        let until_end = self.cap - off;
        let (pad, start) = if len <= until_end {
            (0usize, head)
        } else {
            // Need to pad to the wrap point, then write at the start.
            (until_end, head + until_end as u64)
        };
        if pad + len > free {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }

        // SAFETY: region [head, head+pad+len) is unreachable by the consumer
        // until we publish the new head below.
        let buf = unsafe { &mut *self.buf.get() };
        if pad > 0 {
            // A pad region is always >= 4 bytes (records are 4-byte aligned).
            debug_assert!(pad >= 4);
            buf[off..off + 4].copy_from_slice(&PAD_MARKER.to_le_bytes());
        }
        let s = self.slot(start);
        buf[s..s + 4].copy_from_slice(&(len as u32).to_le_bytes());
        buf[s + 4..s + 8].copy_from_slice(&class_id.to_le_bytes());
        buf[s + 8..s + 16].copy_from_slice(&ts.to_le_bytes());
        buf[s + 16..s + 16 + payload.len()].copy_from_slice(payload);

        self.head.store(start + len as u64, Ordering::Release);
        self.written.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Consumer: drain all available records into `f` as raw record slices
    /// (header included). Returns the number of records drained.
    ///
    /// # Safety contract
    /// Must only be called from the single consumer thread for this ring.
    pub fn drain(&self, mut f: impl FnMut(&[u8])) -> usize {
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        let mut count = 0usize;
        // SAFETY: [tail, head) has been published by the producer.
        let buf = unsafe { &*self.buf.get() };
        while tail < head {
            let off = self.slot(tail);
            let total_len = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
            if total_len == PAD_MARKER {
                tail += (self.cap - off) as u64;
                continue;
            }
            let len = total_len as usize;
            debug_assert!(len >= RECORD_HEADER && off + len <= self.cap);
            f(&buf[off..off + len]);
            tail += len as u64;
            count += 1;
        }
        self.tail.store(tail, Ordering::Release);
        count
    }

    /// Bytes currently buffered and not yet consumed.
    pub fn backlog(&self) -> usize {
        (self.head.load(Ordering::Relaxed) - self.tail.load(Ordering::Relaxed)) as usize
    }
}

/// Parse a raw record slice (as passed to [`RingBuf::drain`]'s callback)
/// into `(class_id, timestamp, payload)`.
pub fn parse_record(rec: &[u8]) -> (u32, u64, &[u8]) {
    let total = u32::from_le_bytes(rec[0..4].try_into().unwrap()) as usize;
    let class_id = u32::from_le_bytes(rec[4..8].try_into().unwrap());
    let ts = u64::from_le_bytes(rec[8..16].try_into().unwrap());
    (class_id, ts, &rec[RECORD_HEADER..total.min(rec.len())])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn roundtrip_single_record() {
        let rb = RingBuf::new(4096);
        assert!(rb.try_write(7, 123, b"hello"));
        let mut seen = vec![];
        rb.drain(|rec| {
            let (id, ts, payload) = parse_record(rec);
            seen.push((id, ts, payload.to_vec()));
        });
        assert_eq!(seen.len(), 1);
        assert_eq!(seen[0].0, 7);
        assert_eq!(seen[0].1, 123);
        // payload is padded to 4-byte multiple; prefix must match
        assert_eq!(&seen[0].2[..5], b"hello");
    }

    #[test]
    fn drops_when_full_and_counts() {
        let rb = RingBuf::new(4096);
        let payload = vec![0u8; 512];
        let mut wrote = 0;
        for _ in 0..100 {
            if rb.try_write(1, 0, &payload) {
                wrote += 1;
            }
        }
        assert!(wrote < 100);
        assert_eq!(rb.dropped() as usize, 100 - wrote);
        assert_eq!(rb.written() as usize, wrote);
    }

    #[test]
    fn wraps_correctly_many_times() {
        let rb = RingBuf::new(4096);
        let mut total = 0u64;
        for round in 0..200u64 {
            let payload = vec![round as u8; (round % 97) as usize];
            assert!(rb.try_write(round as u32, round, &payload));
            let mut got = 0;
            rb.drain(|rec| {
                let (id, ts, p) = parse_record(rec);
                assert_eq!(id, round as u32);
                assert_eq!(ts, round);
                assert_eq!(&p[..payload.len()], &payload[..]);
                got += 1;
            });
            assert_eq!(got, 1);
            total += 1;
        }
        assert_eq!(rb.written(), total);
        assert_eq!(rb.dropped(), 0);
    }

    #[test]
    fn concurrent_producer_consumer_preserves_all_records() {
        let rb = Arc::new(RingBuf::new(1 << 16));
        let n = 50_000u64;
        let prod = {
            let rb = rb.clone();
            std::thread::spawn(move || {
                let mut dropped = 0u64;
                for i in 0..n {
                    let payload = (i as u32).to_le_bytes();
                    if !rb.try_write(9, i, &payload) {
                        dropped += 1;
                        std::thread::yield_now();
                    }
                }
                dropped
            })
        };
        let mut seen = 0u64;
        let mut last_ts = None::<u64>;
        while !prod.is_finished() || rb.backlog() > 0 {
            rb.drain(|rec| {
                let (_, ts, _) = parse_record(rec);
                if let Some(prev) = last_ts {
                    assert!(ts > prev, "per-buffer order must be monotonic");
                }
                last_ts = Some(ts);
                seen += 1;
            });
        }
        let dropped = prod.join().unwrap();
        assert_eq!(seen + dropped, n);
        assert_eq!(rb.dropped(), dropped);
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        assert_eq!(RingBuf::new(5000).capacity(), 8192);
        assert_eq!(RingBuf::new(0).capacity(), 4096);
    }
}
