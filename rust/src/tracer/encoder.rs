//! Payload encoder for trace events.
//!
//! Fields are encoded little-endian, unaligned, in the order declared by
//! the event class descriptor (generated from the API model). In debug
//! builds the encoder cross-checks every pushed value against the
//! descriptor, so a wrapper whose emitted fields drift from the generated
//! trace model fails loudly in tests — the Rust analogue of THAPI's
//! "generated tracepoints cannot drift from the model" guarantee.

use crate::model::{EventClass, FieldType};

/// Encodes one event payload into a scratch buffer.
pub struct Encoder<'a> {
    buf: &'a mut Vec<u8>,
    #[cfg(debug_assertions)]
    class: &'a EventClass,
    #[cfg(debug_assertions)]
    next_field: usize,
}

impl<'a> Encoder<'a> {
    /// Create an encoder writing into `buf` for event class `class`.
    pub fn new(buf: &'a mut Vec<u8>, class: &'a EventClass) -> Self {
        let _ = class;
        Encoder {
            buf,
            #[cfg(debug_assertions)]
            class,
            #[cfg(debug_assertions)]
            next_field: 0,
        }
    }

    #[cfg(debug_assertions)]
    #[inline]
    fn check(&mut self, ty: FieldType) {
        let fields = &self.class.fields;
        assert!(
            self.next_field < fields.len(),
            "event {}: extra field of type {:?} (descriptor has {})",
            self.class.name,
            ty,
            fields.len()
        );
        let want = fields[self.next_field].ty;
        assert!(
            want == ty,
            "event {}: field {} ({}) encoded as {:?}, descriptor says {:?}",
            self.class.name,
            self.next_field,
            fields[self.next_field].name,
            ty,
            want
        );
        self.next_field += 1;
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    fn check(&mut self, _ty: FieldType) {}

    /// Finish: in debug builds asserts all declared fields were encoded.
    pub fn finish(self) {
        #[cfg(debug_assertions)]
        assert!(
            self.next_field == self.class.fields.len(),
            "event {}: encoded {} of {} fields",
            self.class.name,
            self.next_field,
            self.class.fields.len()
        );
    }

    /// Encode a `u32` field.
    #[inline]
    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.check(FieldType::U32);
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    /// Encode a `u64` field.
    #[inline]
    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.check(FieldType::U64);
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    /// Encode an `i64` field.
    #[inline]
    pub fn i64(&mut self, v: i64) -> &mut Self {
        self.check(FieldType::I64);
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    /// Encode an `f64` field.
    #[inline]
    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.check(FieldType::F64);
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
        self
    }

    /// Encode a pointer/handle field (hex-displayed u64).
    #[inline]
    pub fn ptr(&mut self, v: u64) -> &mut Self {
        self.check(FieldType::Ptr);
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    /// Encode a string field (u16 length prefix + UTF-8 bytes, truncated
    /// at 4 KiB to bound record size).
    #[inline]
    pub fn str(&mut self, v: &str) -> &mut Self {
        self.check(FieldType::Str);
        let bytes = v.as_bytes();
        let n = bytes.len().min(4096);
        self.buf.extend_from_slice(&(n as u16).to_le_bytes());
        self.buf.extend_from_slice(&bytes[..n]);
        self
    }
}

/// Decode a payload back into typed values, given the descriptor fields.
/// Used by the BTF reader; the inverse of [`Encoder`].
pub fn decode_payload(fields: &[crate::model::FieldDef], mut p: &[u8]) -> Vec<FieldValue> {
    let mut out = Vec::with_capacity(fields.len());
    for f in fields {
        match f.ty {
            FieldType::U32 => {
                let (v, rest) = p.split_at(4);
                out.push(FieldValue::U64(u32::from_le_bytes(v.try_into().unwrap()) as u64));
                p = rest;
            }
            FieldType::U64 => {
                let (v, rest) = p.split_at(8);
                out.push(FieldValue::U64(u64::from_le_bytes(v.try_into().unwrap())));
                p = rest;
            }
            FieldType::Ptr => {
                let (v, rest) = p.split_at(8);
                out.push(FieldValue::Ptr(u64::from_le_bytes(v.try_into().unwrap())));
                p = rest;
            }
            FieldType::I64 => {
                let (v, rest) = p.split_at(8);
                out.push(FieldValue::I64(i64::from_le_bytes(v.try_into().unwrap())));
                p = rest;
            }
            FieldType::F64 => {
                let (v, rest) = p.split_at(8);
                out.push(FieldValue::F64(f64::from_bits(u64::from_le_bytes(
                    v.try_into().unwrap(),
                ))));
                p = rest;
            }
            FieldType::Str => {
                let (l, rest) = p.split_at(2);
                let n = u16::from_le_bytes(l.try_into().unwrap()) as usize;
                let (s, rest) = rest.split_at(n);
                out.push(FieldValue::Str(String::from_utf8_lossy(s).into_owned()));
                p = rest;
            }
        }
    }
    out
}

/// A decoded field value.
#[derive(Debug, Clone, PartialEq)]
pub enum FieldValue {
    /// Unsigned integer (u32 widened to u64).
    U64(u64),
    /// Signed integer.
    I64(i64),
    /// Floating point.
    F64(f64),
    /// Pointer/handle — displayed in hex.
    Ptr(u64),
    /// String.
    Str(String),
}

impl FieldValue {
    /// Integer view (panics for Str/F64).
    pub fn as_u64(&self) -> u64 {
        match self {
            FieldValue::U64(v) | FieldValue::Ptr(v) => *v,
            FieldValue::I64(v) => *v as u64,
            other => panic!("not an integer field: {other:?}"),
        }
    }

    /// Float view (panics otherwise).
    pub fn as_f64(&self) -> f64 {
        match self {
            FieldValue::F64(v) => *v,
            other => panic!("not a float field: {other:?}"),
        }
    }

    /// String view (panics otherwise).
    pub fn as_str(&self) -> &str {
        match self {
            FieldValue::Str(s) => s,
            other => panic!("not a string field: {other:?}"),
        }
    }

    /// Render for pretty-printing (pointers in hex, like babeltrace2).
    pub fn render(&self) -> String {
        match self {
            FieldValue::U64(v) => v.to_string(),
            FieldValue::I64(v) => v.to_string(),
            FieldValue::F64(v) => format!("{v:.6}"),
            FieldValue::Ptr(v) => format!("{v:#018x}"),
            FieldValue::Str(s) => s.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{EventClass, FieldDef};

    fn class(fields: Vec<FieldDef>) -> EventClass {
        EventClass::new_for_test("test:ev", fields)
    }

    #[test]
    fn roundtrip_all_types() {
        let c = class(vec![
            FieldDef::new("a", FieldType::U32),
            FieldDef::new("b", FieldType::U64),
            FieldDef::new("c", FieldType::I64),
            FieldDef::new("d", FieldType::F64),
            FieldDef::new("e", FieldType::Ptr),
            FieldDef::new("f", FieldType::Str),
        ]);
        let mut buf = Vec::new();
        let mut e = Encoder::new(&mut buf, &c);
        e.u32(7).u64(1 << 40).i64(-3).f64(2.5).ptr(0xff00_0000_dead_beef).str("hi");
        e.finish();
        let vals = decode_payload(&c.fields, &buf);
        assert_eq!(vals[0], FieldValue::U64(7));
        assert_eq!(vals[1], FieldValue::U64(1 << 40));
        assert_eq!(vals[2], FieldValue::I64(-3));
        assert_eq!(vals[3], FieldValue::F64(2.5));
        assert_eq!(vals[4], FieldValue::Ptr(0xff00_0000_dead_beef));
        assert_eq!(vals[5], FieldValue::Str("hi".into()));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "encoded as")]
    fn type_mismatch_panics_in_debug() {
        let c = class(vec![FieldDef::new("a", FieldType::U64)]);
        let mut buf = Vec::new();
        let mut e = Encoder::new(&mut buf, &c);
        e.u32(1);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "encoded 1 of 2")]
    fn missing_field_panics_in_debug() {
        let c = class(vec![
            FieldDef::new("a", FieldType::U64),
            FieldDef::new("b", FieldType::U64),
        ]);
        let mut buf = Vec::new();
        let mut e = Encoder::new(&mut buf, &c);
        e.u64(1);
        e.finish();
    }

    #[test]
    fn ptr_renders_hex() {
        assert_eq!(
            FieldValue::Ptr(0xff).render(),
            "0x00000000000000ff"
        );
    }
}
