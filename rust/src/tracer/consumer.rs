//! Background consumer: drains ring buffers into stream sinks.
//!
//! The LTTng consumer-daemon analogue. Wakes at the session's interval,
//! drains every registered stream's ring into its sink (memory vector,
//! file, or /dev/null-style counter), and performs a final drain on stop
//! so no committed record is lost at teardown.

use super::ringbuf::RECORD_HEADER;
use super::session::{Session, SinkKind};
use std::io::Write;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

pub(super) struct Consumer {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: JoinHandle<()>,
}

impl Consumer {
    /// Start the consumer thread for `session`.
    pub(super) fn start(session: Arc<Session>) -> Self {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("thapi-consumer".into())
            .spawn(move || {
                let interval = session.config.consumer_interval;
                loop {
                    // interruptible sleep: stop() wakes us immediately
                    let (lock, cond) = &*stop2;
                    let guard = lock.lock().unwrap_or_else(|p| p.into_inner());
                    let (guard, _) = cond
                        .wait_timeout_while(guard, interval, |stopped| !*stopped)
                        .unwrap_or_else(|p| p.into_inner());
                    let done = *guard;
                    drop(guard);
                    drain_all(&session);
                    if done {
                        break;
                    }
                }
            })
            .expect("spawn consumer");
        Consumer { stop, handle }
    }

    /// Signal stop and join (includes a final drain).
    pub(super) fn stop(self) {
        let (lock, cond) = &*self.stop;
        *lock.lock().unwrap_or_else(|p| p.into_inner()) = true;
        cond.notify_all();
        let _ = self.handle.join();
    }
}

fn drain_all(session: &Session) {
    // Snapshot the stream list; new streams are picked up next round (and
    // by the final drain, which runs after all producers detached).
    let streams: Vec<_> = session.streams.lock().unwrap().clone();
    for stream in streams {
        let mut drained: u64 = 0;
        match &session.config.sink {
            SinkKind::Null => {
                stream.buf.drain(|rec| {
                    drained += rec.len() as u64;
                });
            }
            SinkKind::Memory | SinkKind::Dir(_) => {
                // Both accumulate into the in-memory stream data; Dir
                // persists at `btf::write_dir` time (trace files are
                // written post-mortem like LTTng's `lttng stop`+archive).
                let mut data = stream.data.lock().unwrap();
                stream.buf.drain(|rec| {
                    debug_assert!(rec.len() >= RECORD_HEADER);
                    data.extend_from_slice(rec);
                    drained += rec.len() as u64;
                });
            }
        }
        if drained > 0 {
            session
                .consumed_bytes
                .fetch_add(drained, Ordering::Relaxed);
        }
    }
    // Flush point for file sinks would go here; memory sinks need none.
    let _ = std::io::sink().flush();
}

#[cfg(test)]
mod tests {
    use crate::model::class_by_name;
    use crate::tracer::session::{
        install_session, test_support, uninstall_session, SessionConfig, SinkKind,
    };
    use crate::tracer::emit;

    #[test]
    fn consumer_drains_while_running() {
        let _g = test_support::lock();
        let session = install_session(SessionConfig {
            consumer_interval: std::time::Duration::from_millis(1),
            ..Default::default()
        });
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        for _ in 0..1000 {
            emit(class, |e| {
                e.u64(1);
            });
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
        let consumed_live = session.stats().consumed_bytes;
        assert!(consumed_live > 0, "consumer should drain while running");
        uninstall_session();
    }

    #[test]
    fn final_drain_loses_nothing() {
        let _g = test_support::lock();
        install_session(SessionConfig {
            // long interval: force the final drain to do all the work
            consumer_interval: std::time::Duration::from_secs(3600),
            ..Default::default()
        });
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let n = 5000;
        for _ in 0..n {
            emit(class, |e| {
                e.u64(1);
            });
        }
        let session = uninstall_session().unwrap();
        let stats = session.stats();
        assert_eq!(stats.written, n);
        // every record is header + 8-byte payload, 4-byte aligned
        assert_eq!(stats.consumed_bytes, n * (16 + 8));
    }

    #[test]
    fn null_sink_counts_but_keeps_nothing() {
        let _g = test_support::lock();
        install_session(SessionConfig {
            sink: SinkKind::Null,
            ..Default::default()
        });
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        for _ in 0..100 {
            emit(class, |e| {
                e.u64(1);
            });
        }
        let session = uninstall_session().unwrap();
        assert!(session.stats().consumed_bytes > 0);
        for s in session.streams.lock().unwrap().iter() {
            assert!(s.data.lock().unwrap().is_empty());
        }
    }
}
