//! Background consumer: drains ring buffers into stream sinks.
//!
//! The LTTng consumer-daemon analogue. Wakes at the session's interval,
//! drains every registered stream's ring into its sink (memory vector,
//! file, /dev/null-style counter, or the live hub), and performs a final
//! drain on stop so no committed record is lost at teardown.
//!
//! For [`SinkKind::Live`] sessions the consumer is also the *decoder and
//! beacon emitter*: every drained record becomes an
//! [`EventMsg`](crate::analysis::EventMsg) try-pushed onto the stream's
//! bounded channel, and after each drain
//! round the consumer publishes per-stream **beacons** — wall-clock
//! watermarks proving a stream quiet — so the live merge can advance
//! global time past idle streams (see `rust/src/live/`).

use super::clock;
use super::ringbuf::{self, RECORD_HEADER};
use super::session::{Session, SinkKind, Stream};
use crate::live::LiveHub;
use std::io::Write;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

pub(super) struct Consumer {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: JoinHandle<()>,
}

impl Consumer {
    /// Start the consumer thread for `session`.
    pub(super) fn start(session: Arc<Session>) -> Self {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("thapi-consumer".into())
            .spawn(move || {
                let interval = session.config.consumer_interval;
                loop {
                    // interruptible sleep: stop() wakes us immediately
                    let (lock, cond) = &*stop2;
                    let guard = lock.lock().unwrap_or_else(|p| p.into_inner());
                    let (guard, _) = cond
                        .wait_timeout_while(guard, interval, |stopped| !*stopped)
                        .unwrap_or_else(|p| p.into_inner());
                    let done = *guard;
                    drop(guard);
                    drain_all(&session);
                    if done {
                        // live sessions: end of stream — unblock the merge
                        if let SinkKind::Live(hub) = &session.config.sink {
                            hub.close_all();
                        }
                        break;
                    }
                }
            })
            .expect("spawn consumer");
        Consumer { stop, handle }
    }

    /// Signal stop and join (includes a final drain).
    pub(super) fn stop(self) {
        let (lock, cond) = &*self.stop;
        *lock.lock().unwrap_or_else(|p| p.into_inner()) = true;
        cond.notify_all();
        let _ = self.handle.join();
    }
}

fn drain_all(session: &Session) {
    // Snapshot the stream list; new streams are picked up next round (and
    // by the final drain, which runs after all producers detached).
    let streams: Vec<_> = session.streams.lock().unwrap().clone();
    if let SinkKind::Live(hub) = &session.config.sink {
        drain_live(session, hub, &streams);
        return;
    }
    for stream in streams {
        let mut drained: u64 = 0;
        match &session.config.sink {
            SinkKind::Null => {
                stream.buf.drain(|rec| {
                    drained += rec.len() as u64;
                });
            }
            SinkKind::Memory | SinkKind::Dir(_) => {
                // Both accumulate into the in-memory stream data; Dir
                // persists at `btf::write_dir` time (trace files are
                // written post-mortem like LTTng's `lttng stop`+archive).
                let mut data = stream.data.lock().unwrap();
                stream.buf.drain(|rec| {
                    debug_assert!(rec.len() >= RECORD_HEADER);
                    data.extend_from_slice(rec);
                    drained += rec.len() as u64;
                });
            }
            SinkKind::Live(_) => unreachable!("handled above"),
        }
        if drained > 0 {
            session
                .consumed_bytes
                .fetch_add(drained, Ordering::Relaxed);
        }
    }
    // Flush point for file sinks would go here; memory sinks need none.
    let _ = std::io::sink().flush();
}

/// One live drain round: decode-and-forward every stream's pending
/// records, then publish beacons for the streams that are provably quiet.
///
/// Channel index i is stream index i (registration order) — the same
/// index a post-mortem `collect` gives the stream, which is what makes
/// the live merge's tie-break byte-identical to `MessageSource`.
///
/// Beacon safety: a beacon value W promises "every record this stream
/// publishes from now on has ts >= W". W is a consumer-side clock read,
/// so the promise needs proof that no producer is holding an
/// already-taken (older) timestamp it has yet to publish. The proof is
/// the emit seqlock bracketing in `session::emit`:
///
/// 1. drain the ring (everything published so far is out);
/// 2. read `emit_seq` — must be even (no emit in flight);
/// 3. read W = now;
/// 4. re-read `emit_seq` — must be unchanged (no emit started around W);
/// 5. re-check the ring is still empty (nothing slipped in before 2.).
///
/// Any emit that begins after step 4 takes its timestamp after W on a
/// globally monotonic clock, so ts >= W holds; any earlier emit either
/// flips the seqlock or lands in the ring and fails 5. If any check
/// fails we simply skip the beacon — the next round (a few ms later)
/// retries, and event pushes advance the watermark meanwhile.
fn drain_live(session: &Session, hub: &LiveHub, streams: &[Arc<Stream>]) {
    hub.ensure_channels(streams.len());
    let mut beacons: Vec<(usize, u64)> = Vec::with_capacity(streams.len());
    for (i, stream) in streams.iter().enumerate() {
        let mut drained: u64 = 0;
        let mut batch = Vec::new();
        let mut raw: Vec<u8> = Vec::new();
        let keep_raw = hub.retain();
        stream.buf.drain(|rec| {
            debug_assert!(rec.len() >= RECORD_HEADER);
            drained += rec.len() as u64;
            if keep_raw {
                raw.extend_from_slice(rec);
            }
            let (id, ts, payload) = ringbuf::parse_record(rec);
            if let Some(msg) = hub.decode(stream.rank, stream.tid, id, ts, payload) {
                batch.push(msg);
            }
        });
        if keep_raw && !raw.is_empty() {
            stream.data.lock().unwrap().extend_from_slice(&raw);
        }
        // Registration barrier, event edition: a stream that registered
        // after this round's snapshot may already hold an event OLDER
        // than everything in `batch` (it registers before taking its
        // first timestamp, while these records were published before our
        // drain). Its (empty, watermark-0 → merge-blocking) channel must
        // exist before this batch becomes releasable, or the merge could
        // emit past the newcomer's first timestamp. Streams registering
        // after this re-snapshot take their first timestamp after the
        // drain above, so they cannot undercut this batch.
        if !batch.is_empty() {
            hub.ensure_channels(session.streams.lock().unwrap().len());
        }
        hub.push_batch(i, batch);
        if drained > 0 {
            session.consumed_bytes.fetch_add(drained, Ordering::Relaxed);
        }
        // Quiescence proof (see above); skip the beacon on any failure.
        let seq1 = stream.emit_seq.load(Ordering::SeqCst);
        if seq1 % 2 == 0 {
            let w = clock::now_ns();
            let seq2 = stream.emit_seq.load(Ordering::SeqCst);
            if seq2 == seq1 && stream.buf.backlog() == 0 {
                beacons.push((i, w));
            }
        }
    }
    // Registration barrier: a stream that registered during this round
    // must have its (empty, watermark-0) channel in place BEFORE any of
    // this round's beacons publish, otherwise the merge could advance
    // past the new stream's first timestamp. Streams registering after
    // this re-snapshot take their first timestamp after our beacon clock
    // reads, so they cannot undercut them.
    hub.ensure_channels(session.streams.lock().unwrap().len());
    for (i, w) in beacons {
        hub.beacon(i, w);
    }
}

#[cfg(test)]
mod tests {
    use crate::model::class_by_name;
    use crate::tracer::session::{
        install_session, test_support, uninstall_session, SessionConfig, SinkKind,
    };
    use crate::tracer::emit;

    #[test]
    fn consumer_drains_while_running() {
        let _g = test_support::lock();
        let session = install_session(SessionConfig {
            consumer_interval: std::time::Duration::from_millis(1),
            ..Default::default()
        });
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        for _ in 0..1000 {
            emit(class, |e| {
                e.u64(1);
            });
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
        let consumed_live = session.stats().consumed_bytes;
        assert!(consumed_live > 0, "consumer should drain while running");
        uninstall_session();
    }

    #[test]
    fn final_drain_loses_nothing() {
        let _g = test_support::lock();
        install_session(SessionConfig {
            // long interval: force the final drain to do all the work
            consumer_interval: std::time::Duration::from_secs(3600),
            ..Default::default()
        });
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let n = 5000;
        for _ in 0..n {
            emit(class, |e| {
                e.u64(1);
            });
        }
        let session = uninstall_session().unwrap();
        let stats = session.stats();
        assert_eq!(stats.written, n);
        // every record is header + 8-byte payload, 4-byte aligned
        assert_eq!(stats.consumed_bytes, n * (16 + 8));
    }

    #[test]
    fn null_sink_counts_but_keeps_nothing() {
        let _g = test_support::lock();
        install_session(SessionConfig {
            sink: SinkKind::Null,
            ..Default::default()
        });
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        for _ in 0..100 {
            emit(class, |e| {
                e.u64(1);
            });
        }
        let session = uninstall_session().unwrap();
        assert!(session.stats().consumed_bytes > 0);
        for s in session.streams.lock().unwrap().iter() {
            assert!(s.data.lock().unwrap().is_empty());
        }
    }
}
