//! Tracing sessions: the global tracer state and the emit hot path.
//!
//! A [`Session`] corresponds to one `lttng create`+`start` cycle: it owns
//! the per-thread ring buffers, the event-class enable bitmap (selective
//! tracing, paper §3.2), the tracing mode, and the background consumer.
//! Install/uninstall swap a global epoch; traced threads cache an `Arc` to
//! the session in TLS and re-validate it with a single atomic load per
//! event, so the emit fast path is: epoch load → bitmap test → encode into
//! TLS scratch → one SPSC ring write. No locks, no allocation (scratch is
//! reused), drop-on-full.

use super::clock;
use super::consumer::Consumer;
use super::encoder::Encoder;
use super::ringbuf::RingBuf;
use crate::model::{class_count, EventClass};
use std::cell::RefCell;
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Tracing modes (paper §5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TracingMode {
    /// Kernel-execution events only: device commands + GPU timings.
    Minimal,
    /// Everything except "non-spawned" polling APIs in spin-lock loops.
    Default,
    /// Every event — debugging only.
    Full,
}

impl TracingMode {
    /// Short label used in reports (T-min / T-default / T-full).
    pub fn label(&self) -> &'static str {
        match self {
            TracingMode::Minimal => "min",
            TracingMode::Default => "default",
            TracingMode::Full => "full",
        }
    }
}

/// Where consumed trace bytes go.
#[derive(Debug, Clone)]
pub enum SinkKind {
    /// Keep streams in memory (returned as `TraceData`; used for
    /// aggregate-only runs, paper §3.7 "local scratchpad").
    Memory,
    /// Persist to a directory (`-t`/`--trace` runs).
    Dir(PathBuf),
    /// Count-and-discard (pure overhead measurement).
    Null,
    /// Live analysis: the consumer decodes records as it drains them and
    /// forwards messages over the hub's bounded per-stream channels
    /// (with beacons for quiet streams), feeding
    /// [`crate::live::LiveSource`] while the application runs. With
    /// `hub.retain()` the raw bytes are additionally kept in memory like
    /// [`SinkKind::Memory`].
    Live(std::sync::Arc<crate::live::LiveHub>),
}

/// Session configuration.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Tracing mode.
    pub mode: TracingMode,
    /// Ring-buffer capacity per thread, bytes.
    pub buffer_capacity: usize,
    /// Trace sink.
    pub sink: SinkKind,
    /// Only trace these ranks (None = all; paper §3.2 "selectively trace
    /// specific groups of ranks").
    pub selected_ranks: Option<HashSet<u32>>,
    /// Hostname recorded in stream headers.
    pub hostname: String,
    /// Consumer wake interval.
    pub consumer_interval: std::time::Duration,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            mode: TracingMode::Default,
            buffer_capacity: 4 << 20,
            sink: SinkKind::Memory,
            selected_ranks: None,
            hostname: "node0".into(),
            consumer_interval: std::time::Duration::from_millis(2),
        }
    }
}

/// One registered per-thread stream.
pub struct Stream {
    /// Logical rank (MPI-style) of the producing thread.
    pub rank: u32,
    /// Process-unique thread id.
    pub tid: u32,
    /// The SPSC ring.
    pub buf: Arc<RingBuf>,
    /// Consumed bytes (memory sink) — drained records land here.
    pub data: Mutex<Vec<u8>>,
    /// Emit-in-progress seqlock, maintained only for live sessions: odd
    /// while the producer is between taking a timestamp and publishing
    /// the record. The consumer reads it to prove quiescence before
    /// publishing a wall-clock beacon — a beacon taken while an emit is
    /// in flight could claim a watermark *above* that event's timestamp
    /// and break the live merge's ordering guarantee.
    pub(super) emit_seq: AtomicU64,
}

/// Aggregate statistics of a finished (or running) session.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SessionStats {
    /// Events committed to ring buffers.
    pub written: u64,
    /// Events dropped (discard mode).
    pub dropped: u64,
    /// Bytes drained by the consumer.
    pub consumed_bytes: u64,
    /// Number of per-thread streams.
    pub streams: usize,
}

/// A tracing session.
pub struct Session {
    /// Immutable configuration.
    pub config: SessionConfig,
    /// Live sink installed: emitters maintain the per-stream emit seqlock
    /// (two extra uncontended atomic ops per event) so the consumer can
    /// publish safe beacons. False for every other sink — the hot path
    /// is unchanged there.
    pub(super) live: bool,
    /// Epoch this session was installed under.
    epoch: u64,
    /// Enable bitmap, one bit per event-class id.
    enabled: Vec<AtomicU64>,
    /// All registered streams.
    pub(super) streams: Mutex<Vec<Arc<Stream>>>,
    /// Bytes drained by the consumer.
    pub(super) consumed_bytes: AtomicU64,
    /// Consumer control.
    consumer: Mutex<Option<Consumer>>,
}

impl Session {
    /// Create a session (not yet installed).
    pub fn new(config: SessionConfig) -> Arc<Self> {
        let n_classes = class_count();
        let words = n_classes.div_ceil(64);
        let enabled: Vec<AtomicU64> = (0..words).map(|_| AtomicU64::new(0)).collect();
        let live = matches!(config.sink, SinkKind::Live(_));
        let s = Arc::new(Session {
            config,
            live,
            epoch: 0,
            enabled,
            streams: Mutex::new(Vec::new()),
            consumed_bytes: AtomicU64::new(0),
            consumer: Mutex::new(None),
        });
        s.apply_mode();
        s
    }

    fn apply_mode(&self) {
        for class in crate::model::all_classes() {
            let on = match self.config.mode {
                TracingMode::Full => true,
                TracingMode::Default => !class.flags.polling,
                TracingMode::Minimal => {
                    class.flags.device_command || class.flags.profiling
                }
            };
            // Sampling classes are always structurally enabled; whether
            // samples exist depends on the daemon being started.
            let on = on || class.flags.sampling;
            self.set_enabled(class.id, on);
        }
    }

    /// Enable/disable one event class by id.
    pub fn set_enabled(&self, id: u32, on: bool) {
        let w = (id / 64) as usize;
        let bit = 1u64 << (id % 64);
        if on {
            self.enabled[w].fetch_or(bit, Ordering::Relaxed);
        } else {
            self.enabled[w].fetch_and(!bit, Ordering::Relaxed);
        }
    }

    /// Disable every class whose name contains `pattern` (event filtering,
    /// like `iprof --filter`).
    pub fn disable_matching(&self, pattern: &str) {
        for class in crate::model::all_classes() {
            if class.name.contains(pattern) {
                self.set_enabled(class.id, false);
            }
        }
    }

    /// Is class `id` enabled?
    #[inline]
    pub fn enabled(&self, id: u32) -> bool {
        let w = (id / 64) as usize;
        (self.enabled[w].load(Ordering::Relaxed) >> (id % 64)) & 1 == 1
    }

    /// Register a stream for a producing thread.
    fn register_stream(&self, rank: u32, tid: u32) -> Arc<Stream> {
        let stream = Arc::new(Stream {
            rank,
            tid,
            buf: Arc::new(RingBuf::new(self.config.buffer_capacity)),
            data: Mutex::new(Vec::new()),
            emit_seq: AtomicU64::new(0),
        });
        self.streams.lock().unwrap().push(stream.clone());
        stream
    }

    /// Current statistics.
    pub fn stats(&self) -> SessionStats {
        let streams = self.streams.lock().unwrap();
        let mut s = SessionStats { streams: streams.len(), ..Default::default() };
        for st in streams.iter() {
            s.written += st.buf.written();
            s.dropped += st.buf.dropped();
        }
        s.consumed_bytes = self.consumed_bytes.load(Ordering::Relaxed);
        s
    }
}

// ---------------------------------------------------------------------------
// Global state + TLS
// ---------------------------------------------------------------------------

/// Epoch: 0 = never installed; odd = active; even(>0) = stopped.
static EPOCH: AtomicU64 = AtomicU64::new(0);
static CURRENT: RwLock<Option<Arc<Session>>> = RwLock::new(None);
static NEXT_TID: AtomicU32 = AtomicU32::new(1);

struct ThreadCtx {
    epoch: u64,
    rank: u32,
    tid: u32,
    stream: Option<Arc<Stream>>,
    session: Option<Arc<Session>>,
    scratch: Vec<u8>,
}

thread_local! {
    static CTX: RefCell<ThreadCtx> = RefCell::new(ThreadCtx {
        epoch: 0,
        rank: 0,
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        stream: None,
        session: None,
        scratch: Vec::with_capacity(512),
    });
}

/// Set the logical rank of the calling thread (MPI substrate and engine
/// workers call this; default rank is 0).
pub fn set_thread_rank(rank: u32) {
    CTX.with(|c| {
        let mut c = c.borrow_mut();
        c.rank = rank;
        // force re-registration so the stream is tagged with the new rank
        c.epoch = 0;
        c.stream = None;
        c.session = None;
    });
}

/// Pre-register the calling thread with the active session (optional —
/// registration is otherwise lazy on first emit).
pub fn register_thread() {
    CTX.with(|c| {
        let mut c = c.borrow_mut();
        revalidate(&mut c);
    });
}

fn revalidate(c: &mut ThreadCtx) {
    let epoch = EPOCH.load(Ordering::Acquire);
    c.epoch = epoch;
    c.stream = None;
    c.session = None;
    if epoch % 2 == 1 {
        let guard = CURRENT.read().unwrap_or_else(|p| p.into_inner());
        if let Some(sess) = guard.as_ref() {
            if sess.epoch == epoch {
                let traced = sess
                    .config
                    .selected_ranks
                    .as_ref()
                    .map(|set| set.contains(&c.rank))
                    .unwrap_or(true);
                if traced {
                    c.stream = Some(sess.register_stream(c.rank, c.tid));
                }
                c.session = Some(sess.clone());
            }
        }
    }
}

/// Install a session and start its consumer. Panics if one is active.
pub fn install_session(config: SessionConfig) -> Arc<Session> {
    clock::init();
    assert!(
        EPOCH.load(Ordering::Relaxed) % 2 == 0,
        "a tracing session is already active"
    );
    let mut guard = CURRENT.write().unwrap_or_else(|p| p.into_inner());
    let mut session = Session::new(config);
    let epoch = EPOCH.load(Ordering::Relaxed) + 1;
    // Session::new returns Arc; set its epoch via Arc::get_mut (sole owner).
    Arc::get_mut(&mut session).unwrap().epoch = epoch;
    *session.consumer.lock().unwrap() = Some(Consumer::start(session.clone()));
    *guard = Some(session.clone());
    EPOCH.store(epoch, Ordering::Release);
    session
}

/// Stop the active session: bump the epoch so emitters detach, stop the
/// consumer (final drain included), and return the session.
pub fn uninstall_session() -> Option<Arc<Session>> {
    let mut guard = CURRENT.write().unwrap_or_else(|p| p.into_inner());
    let session = guard.take()?;
    EPOCH.store(session.epoch + 1, Ordering::Release);
    if let Some(consumer) = session.consumer.lock().unwrap().take() {
        consumer.stop();
    }
    Some(session)
}

/// Stats of the active session, if any.
pub fn session_stats() -> Option<SessionStats> {
    CURRENT
        .read()
        .unwrap_or_else(|p| p.into_inner())
        .as_ref()
        .map(|s| s.stats())
}

/// Emit one event. `fill` encodes the payload fields in descriptor order.
///
/// This is the tracepoint hot path; when no session is active, or the
/// class is disabled, the cost is one or two atomic loads.
#[inline]
pub fn emit<F: FnOnce(&mut Encoder)>(class: &'static EventClass, fill: F) {
    let epoch = EPOCH.load(Ordering::Acquire);
    if epoch % 2 == 0 {
        return;
    }
    CTX.with(|c| {
        let mut c = c.borrow_mut();
        if c.epoch != epoch {
            revalidate(&mut c);
        }
        // Disjoint field borrows: no Arc refcount traffic on the hot path.
        let ThreadCtx { session, stream, scratch, .. } = &mut *c;
        let Some(session) = session.as_ref() else { return };
        if !session.enabled(class.id) {
            return;
        }
        let Some(stream) = stream.as_ref() else { return };
        // Live sessions only: open the emit seqlock BEFORE taking the
        // timestamp, close it AFTER publishing. The consumer's beacon
        // protocol (consumer.rs) relies on this bracketing: if it reads
        // an even, unchanged seq around a clock read W with an empty
        // ring, every event this stream ever publishes later must carry
        // ts >= W (the trace clock is globally monotonic).
        let live = session.live;
        if live {
            stream.emit_seq.fetch_add(1, Ordering::SeqCst);
        }
        let ts = clock::now_ns();
        scratch.clear();
        let mut enc = Encoder::new(scratch, class);
        fill(&mut enc);
        enc.finish();
        stream.buf.try_write(class.id, ts, scratch);
        if live {
            stream.emit_seq.fetch_add(1, Ordering::SeqCst);
        }
    });
}

#[cfg(test)]
pub(crate) mod test_support {
    //! Global-session tests must not run concurrently; every test that
    //! installs a session takes this lock.
    use std::sync::{Mutex, MutexGuard};
    static LOCK: Mutex<()> = Mutex::new(());
    pub fn lock() -> MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::class_by_name;

    #[test]
    fn emit_without_session_is_noop() {
        let _g = test_support::lock();
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        emit(class, |e| {
            e.u64(0);
        });
        // nothing to assert beyond "did not crash / did not register"
    }

    #[test]
    fn session_records_events() {
        let _g = test_support::lock();
        let session = install_session(SessionConfig::default());
        let entry = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let exit = class_by_name("lttng_ust_ze:zeInit_exit").unwrap();
        for _ in 0..100 {
            emit(entry, |e| {
                e.u64(0);
            });
            emit(exit, |e| {
                e.u64(0);
            });
        }
        let got = uninstall_session().unwrap();
        assert!(Arc::ptr_eq(&session, &got));
        let stats = got.stats();
        assert_eq!(stats.written, 200);
        assert_eq!(stats.dropped, 0);
        assert!(stats.consumed_bytes > 0);
    }

    #[test]
    fn minimal_mode_disables_host_api_classes() {
        let _g = test_support::lock();
        let session = install_session(SessionConfig {
            mode: TracingMode::Minimal,
            ..Default::default()
        });
        let init = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let memcpy = class_by_name("lttng_ust_ze:zeCommandListAppendMemoryCopy_entry").unwrap();
        assert!(!session.enabled(init.id));
        assert!(session.enabled(memcpy.id));
        emit(init, |e| {
            e.u64(0);
        });
        let got = uninstall_session().unwrap();
        assert_eq!(got.stats().written, 0);
    }

    #[test]
    fn default_mode_excludes_polling() {
        let _g = test_support::lock();
        let session = install_session(SessionConfig::default());
        let q = class_by_name("lttng_ust_ze:zeEventQueryStatus_entry").unwrap();
        let s = class_by_name("lttng_ust_ze:zeEventHostSynchronize_entry").unwrap();
        assert!(!session.enabled(q.id));
        assert!(session.enabled(s.id));
        uninstall_session();
    }

    #[test]
    fn full_mode_enables_everything() {
        let _g = test_support::lock();
        let session = install_session(SessionConfig {
            mode: TracingMode::Full,
            ..Default::default()
        });
        for c in crate::model::all_classes() {
            assert!(session.enabled(c.id), "{} disabled in full mode", c.name);
        }
        uninstall_session();
    }

    #[test]
    fn rank_selection_drops_unselected_ranks() {
        let _g = test_support::lock();
        let mut selected = HashSet::new();
        selected.insert(5u32);
        install_session(SessionConfig {
            selected_ranks: Some(selected),
            ..Default::default()
        });
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        // this thread has rank 0 (or whatever previous tests set) — force it
        set_thread_rank(0);
        emit(class, |e| {
            e.u64(0);
        });
        set_thread_rank(5);
        emit(class, |e| {
            e.u64(0);
        });
        let got = uninstall_session().unwrap();
        let stats = got.stats();
        assert_eq!(stats.written, 1, "only the rank-5 event is kept");
        set_thread_rank(0);
    }

    #[test]
    fn disable_matching_filters_by_pattern() {
        let _g = test_support::lock();
        let session = install_session(SessionConfig::default());
        session.disable_matching("lttng_ust_cuda");
        let cu = class_by_name("lttng_ust_cuda:cuInit_entry").unwrap();
        let ze = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        assert!(!session.enabled(cu.id));
        assert!(session.enabled(ze.id));
        uninstall_session();
    }

    #[test]
    #[should_panic(expected = "already active")]
    fn double_install_panics() {
        let _g = test_support::lock();
        let _s = install_session(SessionConfig::default());
        // ensure cleanup even though we panic
        struct Cleanup;
        impl Drop for Cleanup {
            fn drop(&mut self) {
                uninstall_session();
            }
        }
        let _c = Cleanup;
        install_session(SessionConfig::default());
    }
}
