//! Trace clock: monotonic nanoseconds since process trace-clock origin.
//!
//! LTTng timestamps events from the TSC (constant-rate invariant
//! timestamp counter) rather than `clock_gettime`, because a tracepoint
//! must cost nanoseconds and a vDSO call costs ~20 ns by itself. We do
//! the same on x86_64: `rdtsc` calibrated once against `Instant`, with a
//! `clock_gettime`-based fallback elsewhere. Analysis only ever uses
//! differences and ordering, so an arbitrary per-process origin is fine.
//!
//! The simulated *device* clock conversion happens in the engines (they
//! timestamp commands with this same clock at execution, mirroring what
//! THAPI's GPU-profiling helpers reconstruct at synchronize time).

use once_cell::sync::Lazy;
use std::time::Instant;

static ORIGIN: Lazy<Instant> = Lazy::new(Instant::now);

#[cfg(target_arch = "x86_64")]
mod tsc {
    use super::ORIGIN;
    use once_cell::sync::Lazy;

    /// ns per 2^20 TSC ticks (fixed-point), plus the TSC value at origin.
    pub(super) struct Calib {
        pub t0: u64,
        pub ns_per_tick_x2_20: u64,
    }

    pub(super) static CALIB: Lazy<Calib> = Lazy::new(|| {
        // Calibrate: measure TSC rate against Instant over a short window.
        let i0 = *ORIGIN;
        let t0 = unsafe { core::arch::x86_64::_rdtsc() };
        let spin_start = std::time::Instant::now();
        while spin_start.elapsed().as_micros() < 2_000 {
            std::hint::spin_loop();
        }
        let t1 = unsafe { core::arch::x86_64::_rdtsc() };
        let dt_ns = i0.elapsed().as_nanos() as u64;
        let base_ns = dt_ns - spin_start.elapsed().as_nanos() as u64;
        let ticks = (t1 - t0).max(1);
        let window_ns = dt_ns - base_ns;
        Calib {
            // back-date t0 to the trace origin
            t0: t0.saturating_sub(base_ns * ticks / window_ns.max(1)),
            ns_per_tick_x2_20: (window_ns << 20) / ticks,
        }
    });
}

/// Nanoseconds since the trace-clock origin.
#[inline]
pub fn now_ns() -> u64 {
    #[cfg(target_arch = "x86_64")]
    {
        let c = &*tsc::CALIB;
        let t = unsafe { core::arch::x86_64::_rdtsc() };
        ((t.saturating_sub(c.t0) as u128 * c.ns_per_tick_x2_20 as u128) >> 20) as u64
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        ORIGIN.elapsed().as_nanos() as u64
    }
}

/// Force-initialize the origin and TSC calibration (call early so
/// timestamps start near zero and the first tracepoint doesn't pay the
/// ~2 ms calibration).
pub fn init() {
    Lazy::force(&ORIGIN);
    #[cfg(target_arch = "x86_64")]
    Lazy::force(&tsc::CALIB);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic() {
        init();
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }

    #[test]
    fn advances() {
        init();
        let a = now_ns();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(now_ns() - a >= 1_000_000);
    }

    #[test]
    fn tracks_wall_time_within_five_percent() {
        init();
        let w0 = Instant::now();
        let a = now_ns();
        std::thread::sleep(std::time::Duration::from_millis(50));
        let dt_trace = (now_ns() - a) as f64;
        let dt_wall = w0.elapsed().as_nanos() as f64;
        let err = (dt_trace - dt_wall).abs() / dt_wall;
        assert!(err < 0.05, "trace clock drift {err:.3} vs wall");
    }
}
