//! BTF — the Binary Trace Format (CTF stand-in).
//!
//! Like CTF, a BTF trace is a **metadata stream** (text, generated from the
//! trace model: every event class with id, name, api and typed fields, plus
//! an env block) and a set of **binary event streams** (one per traced
//! thread, raw ring-buffer records). The analysis layer parses traces
//! through this module only — it never touches the live registry — so
//! post-mortem analysis is genuinely offline, like Babeltrace2 reading CTF.

use super::ringbuf;
use super::session::{Session, SinkKind};
use crate::model::{FieldDef, FieldType};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

/// Magic for stream files.
const STREAM_MAGIC: &[u8; 4] = b"BTFS";
/// Format version.
const VERSION: u32 = 1;

/// A whole trace: metadata + streams. The in-memory form; `write_dir` /
/// `read_dir` persist and reload it.
#[derive(Debug, Clone, Default)]
pub struct TraceData {
    /// Metadata text (event descriptors + env).
    pub metadata: String,
    /// Binary event streams.
    pub streams: Vec<StreamData>,
}

/// One per-thread event stream.
#[derive(Debug, Clone)]
pub struct StreamData {
    /// Hostname of the producing node.
    pub hostname: String,
    /// Logical rank.
    pub rank: u32,
    /// Process-unique thread id.
    pub tid: u32,
    /// Raw records (ring-buffer wire format).
    pub bytes: Vec<u8>,
}

impl TraceData {
    /// Total payload bytes across streams (the paper's "space requirement").
    pub fn size_bytes(&self) -> u64 {
        self.streams.iter().map(|s| s.bytes.len() as u64).sum::<u64>()
            + self.metadata.len() as u64
    }

    /// Total record count.
    pub fn record_count(&self) -> u64 {
        let mut n = 0;
        for s in &self.streams {
            iter_records(&s.bytes, |_, _, _| n += 1);
        }
        n
    }
}

/// Iterate raw records of one stream: `f(class_id, ts, payload)`.
pub fn iter_records(bytes: &[u8], mut f: impl FnMut(u32, u64, &[u8])) {
    let mut off = 0usize;
    while off + ringbuf::RECORD_HEADER <= bytes.len() {
        let total = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        if total == ringbuf::PAD_MARKER {
            break; // padding never reaches stream files
        }
        let total = total as usize;
        let (id, ts, payload) = ringbuf::parse_record(&bytes[off..off + total]);
        f(id, ts, payload);
        off += total;
    }
}

// ---------------------------------------------------------------------------
// Metadata generation + parsing
// ---------------------------------------------------------------------------

fn field_type_name(t: FieldType) -> &'static str {
    match t {
        FieldType::U32 => "u32",
        FieldType::U64 => "u64",
        FieldType::I64 => "i64",
        FieldType::F64 => "f64",
        FieldType::Ptr => "ptr",
        FieldType::Str => "str",
    }
}

fn field_type_from_name(s: &str) -> Result<FieldType> {
    Ok(match s {
        "u32" => FieldType::U32,
        "u64" => FieldType::U64,
        "i64" => FieldType::I64,
        "f64" => FieldType::F64,
        "ptr" => FieldType::Ptr,
        "str" => FieldType::Str,
        other => bail!("unknown field type {other}"),
    })
}

/// Generate the metadata text from the live registry plus env entries.
pub fn generate_metadata(env: &[(String, String)]) -> String {
    let mut out = String::new();
    out.push_str("btf_version: 1\n");
    out.push_str("env:\n");
    for (k, v) in env {
        out.push_str(&format!("  {k}: {v}\n"));
    }
    out.push_str("events:\n");
    for class in crate::model::all_classes() {
        out.push_str(&format!(
            "  - id: {}\n    name: {}\n    api: {}\n    flags: {}{}{}{}\n    fields:\n",
            class.id,
            class.name,
            class.api.backend_label(),
            if class.flags.host_api { "h" } else { "" },
            if class.flags.polling { "p" } else { "" },
            if class.flags.device_command { "d" } else { "" },
            if class.flags.profiling {
                "g"
            } else if class.flags.sampling {
                "s"
            } else {
                ""
            },
        ));
        for f in &class.fields {
            out.push_str(&format!("      - {}: {}\n", f.name, field_type_name(f.ty)));
        }
    }
    out
}

/// A decoded event-class descriptor as parsed back from metadata — what
/// analysis plugins see (decoupled from the live registry).
#[derive(Debug, Clone)]
pub struct DecodedClass {
    /// Class id (index into streams' records).
    pub id: u32,
    /// Full event name.
    pub name: String,
    /// Backend label (ZE, CUDA, ...).
    pub api: String,
    /// Flags string (h=host, p=polling, d=device-cmd, g=gpu-profiling,
    /// s=sampling).
    pub flags: String,
    /// Typed fields in wire order.
    pub fields: Vec<FieldDef>,
}

impl DecodedClass {
    /// Strip provider + `_entry`/`_exit`.
    pub fn api_function(&self) -> &str {
        let base = self.name.split(':').nth(1).unwrap_or(&self.name);
        base.strip_suffix("_entry")
            .or_else(|| base.strip_suffix("_exit"))
            .unwrap_or(base)
    }

    /// Is an `_entry` class.
    pub fn is_entry(&self) -> bool {
        self.name.ends_with("_entry")
    }

    /// Is an `_exit` class.
    pub fn is_exit(&self) -> bool {
        self.name.ends_with("_exit")
    }
}

/// Decoded-class table of the *live registry* (metadata emit→parse
/// round trip), keyed by class id. This is how on-line consumers (live
/// mode) decode ring records the moment they are drained, through the
/// same descriptor path post-mortem analysis uses — never the registry
/// structs themselves, preserving the "analysis reads metadata only"
/// decoupling.
pub fn registry_classes() -> HashMap<u32, std::sync::Arc<DecodedClass>> {
    let md = parse_metadata(&generate_metadata(&[]))
        .expect("generated registry metadata must parse");
    md.classes
        .into_iter()
        .map(|(id, c)| (id, std::sync::Arc::new(c)))
        .collect()
}

/// Parsed metadata: env + class table indexed by id.
#[derive(Debug, Clone, Default)]
pub struct Metadata {
    /// Env entries.
    pub env: Vec<(String, String)>,
    /// Classes by id.
    pub classes: HashMap<u32, DecodedClass>,
}

/// Parse metadata text.
pub fn parse_metadata(text: &str) -> Result<Metadata> {
    let mut md = Metadata::default();
    let mut in_env = false;
    let mut in_events = false;
    let mut current: Option<DecodedClass> = None;
    for line in text.lines() {
        if line.starts_with("env:") {
            in_env = true;
            in_events = false;
            continue;
        }
        if line.starts_with("events:") {
            in_events = true;
            in_env = false;
            continue;
        }
        if in_env && line.starts_with("  ") {
            if let Some((k, v)) = line.trim().split_once(':') {
                md.env.push((k.trim().into(), v.trim().into()));
            }
            continue;
        }
        if !in_events {
            continue;
        }
        let t = line.trim_start();
        if let Some(rest) = t.strip_prefix("- id:") {
            if let Some(c) = current.take() {
                md.classes.insert(c.id, c);
            }
            current = Some(DecodedClass {
                id: rest.trim().parse().context("bad id")?,
                name: String::new(),
                api: String::new(),
                flags: String::new(),
                fields: Vec::new(),
            });
        } else if let Some(rest) = t.strip_prefix("name:") {
            current.as_mut().context("name before id")?.name = rest.trim().into();
        } else if let Some(rest) = t.strip_prefix("api:") {
            current.as_mut().context("api before id")?.api = rest.trim().into();
        } else if let Some(rest) = t.strip_prefix("flags:") {
            current.as_mut().context("flags before id")?.flags = rest.trim().into();
        } else if t.starts_with("fields:") {
            // list follows
        } else if let Some(rest) = t.strip_prefix("- ") {
            let (name, ty) = rest.rsplit_once(':').context("bad field line")?;
            current
                .as_mut()
                .context("field before id")?
                .fields
                .push(FieldDef::new(name.trim(), field_type_from_name(ty.trim())?));
        }
    }
    if let Some(c) = current.take() {
        md.classes.insert(c.id, c);
    }
    Ok(md)
}

// ---------------------------------------------------------------------------
// Session -> TraceData, and disk persistence
// ---------------------------------------------------------------------------

/// Collect a stopped session's streams into a [`TraceData`]. `env` extends
/// the generated metadata env block.
pub fn collect(session: &Session, env: &[(String, String)]) -> TraceData {
    let mut full_env = vec![
        ("tracer".to_string(), format!("thapi-rs {}", crate::version())),
        ("hostname".to_string(), session.config.hostname.clone()),
        ("mode".to_string(), session.config.mode.label().to_string()),
    ];
    full_env.extend(env.iter().cloned());
    let metadata = generate_metadata(&full_env);
    let mut streams = Vec::new();
    for s in session.streams.lock().unwrap().iter() {
        let bytes = std::mem::take(&mut *s.data.lock().unwrap());
        streams.push(StreamData {
            hostname: session.config.hostname.clone(),
            rank: s.rank,
            tid: s.tid,
            bytes,
        });
    }
    let trace = TraceData { metadata, streams };
    if let SinkKind::Dir(dir) = &session.config.sink {
        // Persist as requested; failures here are fatal for -t runs.
        write_dir(&trace, dir).expect("failed to persist trace directory");
    }
    trace
}

/// Persist a trace to a directory: `metadata.btf` + `stream_R_T.btfs`.
pub fn write_dir(trace: &TraceData, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join("metadata.btf"), &trace.metadata)?;
    for s in &trace.streams {
        let path = dir.join(format!("stream_{}_{}.btfs", s.rank, s.tid));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(STREAM_MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&s.rank.to_le_bytes())?;
        f.write_all(&s.tid.to_le_bytes())?;
        let host = s.hostname.as_bytes();
        f.write_all(&(host.len() as u16).to_le_bytes())?;
        f.write_all(host)?;
        f.write_all(&s.bytes)?;
    }
    Ok(())
}

/// Load a trace from a directory written by [`write_dir`].
pub fn read_dir(dir: &Path) -> Result<TraceData> {
    let metadata = std::fs::read_to_string(dir.join("metadata.btf"))
        .with_context(|| format!("no metadata.btf in {}", dir.display()))?;
    let mut streams = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.extension().map(|e| e != "btfs").unwrap_or(true) {
            continue;
        }
        let mut f = std::fs::File::open(&path)?;
        let mut head = [0u8; 4 + 4 + 4 + 4 + 2];
        f.read_exact(&mut head)?;
        if &head[0..4] != STREAM_MAGIC {
            bail!("{}: bad magic", path.display());
        }
        let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
        if version != VERSION {
            bail!("{}: unsupported version {version}", path.display());
        }
        let rank = u32::from_le_bytes(head[8..12].try_into().unwrap());
        let tid = u32::from_le_bytes(head[12..16].try_into().unwrap());
        let hlen = u16::from_le_bytes(head[16..18].try_into().unwrap()) as usize;
        let mut hostname = vec![0u8; hlen];
        f.read_exact(&mut hostname)?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        streams.push(StreamData {
            hostname: String::from_utf8_lossy(&hostname).into_owned(),
            rank,
            tid,
            bytes,
        });
    }
    streams.sort_by_key(|s| (s.rank, s.tid));
    Ok(TraceData { metadata, streams })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::class_by_name;
    use crate::tracer::session::{
        install_session, test_support, uninstall_session, SessionConfig,
    };
    use crate::tracer::emit;

    #[test]
    fn metadata_roundtrip_covers_all_classes() {
        let md_text = generate_metadata(&[("k".into(), "v".into())]);
        let md = parse_metadata(&md_text).unwrap();
        assert_eq!(md.classes.len(), crate::model::class_count());
        assert!(md.env.iter().any(|(k, v)| k == "k" && v == "v"));
        // spot-check one descriptor field-for-field
        let live = class_by_name("lttng_ust_cuda:cuMemGetInfo_exit").unwrap();
        let dec = &md.classes[&live.id];
        assert_eq!(dec.name, live.name);
        assert_eq!(dec.fields.len(), live.fields.len());
        for (a, b) in dec.fields.iter().zip(&live.fields) {
            assert_eq!(a, b);
        }
        assert_eq!(dec.api, "CUDA");
        assert!(dec.is_exit());
        assert_eq!(dec.api_function(), "cuMemGetInfo");
    }

    #[test]
    fn collect_write_read_roundtrip() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        for i in 0..50 {
            emit(class, |e| {
                e.u64(i);
            });
        }
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[("app".into(), "test".into())]);
        assert_eq!(trace.record_count(), 50);

        let dir = std::env::temp_dir().join(format!("btf_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        write_dir(&trace, &dir).unwrap();
        let back = read_dir(&dir).unwrap();
        assert_eq!(back.record_count(), 50);
        assert_eq!(back.metadata, trace.metadata);
        assert_eq!(back.streams.len(), trace.streams.len());
        let s0 = &back.streams[0];
        let o0 = trace.streams.iter().find(|s| s.tid == s0.tid).unwrap();
        assert_eq!(s0.bytes, o0.bytes);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn iter_records_decodes_payloads() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let class = class_by_name("lttng_ust_ze:zeCommandQueueSynchronize_entry").unwrap();
        emit(class, |e| {
            e.ptr(0xabcd).u64(u64::MAX);
        });
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let md = parse_metadata(&trace.metadata).unwrap();
        let mut hits = 0;
        for s in &trace.streams {
            iter_records(&s.bytes, |id, _ts, payload| {
                let dec = &md.classes[&id];
                assert_eq!(dec.name, class.name);
                let vals = crate::tracer::encoder::decode_payload(&dec.fields, payload);
                assert_eq!(vals[0].as_u64(), 0xabcd);
                assert_eq!(vals[1].as_u64(), u64::MAX);
                hits += 1;
            });
        }
        assert_eq!(hits, 1);
    }
}
