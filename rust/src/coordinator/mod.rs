//! The `iprof` coordinator: session lifecycle + workload execution +
//! post-mortem analysis dispatch (paper §3.4 "Tracing begins by launching
//! the application using the iprof launcher").
//!
//! [`IprofConfig`] mirrors the paper's launcher knobs: tracing mode
//! (minimal/default/full), device sampling on/off (+ interval), event
//! filtering, rank selection, trace-vs-aggregate persistence. [`run`]
//! executes one workload under one configuration and returns a
//! [`RunReport`] with wall time, tracer statistics and the requested
//! analyses — the building block of every §5 experiment.

use crate::analysis::{self, AnalysisSink, Report as AnalysisReport, Tally};
use anyhow::Result;
use crate::apps::Workload;
use crate::device::Node;
use crate::live::{self, LatencySummary, LiveConfig, LiveHub, LiveSource, LiveStats, OriginStats};
use crate::remote::{
    self, Broadcaster, FanIn, FanInStats, PublishStats, Publisher, ReconnectPolicy, RemoteStats,
    ServeOutcome, SubscriberStats,
};
use crate::sampling::{Sampler, SamplingConfig};
use crate::telemetry::{TelemetryExposure, TelemetryOptions};
use crate::tracer::btf::{self, TraceData};
use crate::tracer::{
    install_session, uninstall_session, SessionConfig, SessionStats, SinkKind, TracingMode,
};
use std::collections::HashSet;
use std::io::{Read, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Launcher configuration (the `iprof` CLI surface).
#[derive(Debug, Clone)]
pub struct IprofConfig {
    /// Tracing enabled at all (false = baseline run).
    pub tracing: bool,
    /// Tracing mode.
    pub mode: TracingMode,
    /// Device sampling daemon (TS-* configurations).
    pub sampling: Option<SamplingConfig>,
    /// Trace sink.
    pub sink: SinkKind,
    /// Rank selection (None = all ranks).
    pub selected_ranks: Option<HashSet<u32>>,
    /// Event-name substring filters to disable.
    pub disabled_patterns: Vec<String>,
    /// Ring-buffer capacity per thread.
    pub buffer_capacity: usize,
}

impl Default for IprofConfig {
    fn default() -> Self {
        IprofConfig {
            tracing: true,
            mode: TracingMode::Default,
            sampling: None,
            sink: SinkKind::Memory,
            selected_ranks: None,
            disabled_patterns: Vec::new(),
            buffer_capacity: 8 << 20,
        }
    }
}

impl IprofConfig {
    /// Baseline (untraced) run.
    pub fn baseline() -> Self {
        IprofConfig { tracing: false, ..Default::default() }
    }

    /// One of the six §5.2 configurations: T-{min,default,full} and
    /// TS-{min,default,full}.
    pub fn paper_config(mode: TracingMode, sampling: bool) -> Self {
        IprofConfig {
            tracing: true,
            mode,
            sampling: if sampling { Some(SamplingConfig::default()) } else { None },
            ..Default::default()
        }
    }

    /// Label like "T-default" / "TS-min" (baseline: "base").
    pub fn label(&self) -> String {
        if !self.tracing {
            return "base".into();
        }
        let prefix = if self.sampling.is_some() { "TS" } else { "T" };
        format!("{prefix}-{}", self.mode.label())
    }
}

/// Result of one `iprof` run.
#[derive(Debug)]
pub struct RunReport {
    /// Workload name.
    pub app: String,
    /// Configuration label.
    pub config: String,
    /// Application wall time.
    pub wall: Duration,
    /// Tracer statistics (None for baseline).
    pub stats: Option<SessionStats>,
    /// The collected trace (None for baseline / Null sink).
    pub trace: Option<TraceData>,
}

impl RunReport {
    /// Trace size in bytes (0 if none).
    pub fn trace_bytes(&self) -> u64 {
        self.trace.as_ref().map(|t| t.size_bytes()).unwrap_or(0)
    }

    /// Run the tally analysis over the collected trace in one streaming
    /// pass (lazy muxing + incremental interval pairing — no
    /// materialized `Vec<EventMsg>`).
    pub fn tally(&self) -> Option<Tally> {
        let trace = self.trace.as_ref()?;
        let parsed = analysis::parse_trace(trace).ok()?;
        Some(Tally::from_parsed(&parsed))
    }

    /// Drive an arbitrary set of analysis sinks from one streaming pass
    /// over the collected trace. Returns `None` for baseline runs
    /// (no trace), one [`AnalysisReport`] per sink otherwise.
    pub fn analyze(
        &self,
        sinks: &mut [Box<dyn AnalysisSink + '_>],
    ) -> Option<Result<Vec<AnalysisReport>>> {
        let trace = self.trace.as_ref()?;
        Some(analysis::parse_trace(trace).map(|parsed| analysis::run_pipeline(&parsed, sinks)))
    }
}

/// Run `workload` on `node` under `config`.
pub fn run(node: &Arc<Node>, workload: &dyn Workload, config: &IprofConfig) -> RunReport {
    if !config.tracing {
        let t0 = Instant::now();
        workload.run(node);
        node.synchronize();
        return RunReport {
            app: workload.name().to_string(),
            config: config.label(),
            wall: t0.elapsed(),
            stats: None,
            trace: None,
        };
    }

    let session = install_session(SessionConfig {
        mode: config.mode,
        buffer_capacity: config.buffer_capacity,
        sink: config.sink.clone(),
        selected_ranks: config.selected_ranks.clone(),
        hostname: node.config.hostname.clone(),
        consumer_interval: Duration::from_millis(2),
    });
    for p in &config.disabled_patterns {
        session.disable_matching(p);
    }
    let sampler = config
        .sampling
        .clone()
        .map(|s| Sampler::start(node.clone(), s));

    let t0 = Instant::now();
    workload.run(node);
    node.synchronize();
    let wall = t0.elapsed();

    if let Some(s) = sampler {
        s.stop();
    }
    let session = uninstall_session().expect("session vanished");
    let stats = session.stats();
    let trace = match config.sink {
        SinkKind::Null => None,
        _ => Some(btf::collect(
            &session,
            &[("app".to_string(), workload.name().to_string())],
        )),
    };
    RunReport {
        app: workload.name().to_string(),
        config: config.label(),
        wall,
        stats: Some(stats),
        trace,
    }
}

/// Result of one live `iprof --live` run: the usual run report fields
/// plus the live-transport statistics and the on-line analysis output.
#[derive(Debug)]
pub struct LiveRunReport {
    /// Workload name.
    pub app: String,
    /// Configuration label.
    pub config: String,
    /// Application wall time.
    pub wall: Duration,
    /// Tracer statistics (ring-level written/dropped).
    pub stats: SessionStats,
    /// The collected trace — only with [`LiveConfig::retain`] (used by
    /// the live-vs-post-mortem equivalence tests), `None` in production
    /// live mode where nothing trace-sized is ever materialized.
    pub trace: Option<TraceData>,
    /// Channel-level statistics: received/dropped/beacons.
    pub live: LiveStats,
    /// One final report per sink, in sink order — same contract as
    /// [`RunReport::analyze`], produced on-line.
    pub reports: Vec<AnalysisReport>,
    /// Merge latency: how stale each message was when analyzed.
    pub latency: LatencySummary,
}

impl LiveRunReport {
    /// Total events lost to backpressure anywhere on the live path
    /// (ring discard + channel drop). Zero means the on-line reports
    /// cover exactly what a post-mortem run would have seen.
    pub fn total_dropped(&self) -> u64 {
        self.stats.dropped.saturating_add(self.live.dropped)
    }
}

/// Run `workload` under `config` with **on-line analysis**: the session's
/// consumer thread decodes records as it drains them and feeds `sinks`
/// through the live hub while the workload executes
/// (ROADMAP: "`run_pipeline` feeds from the session's consumer thread
/// instead of a collected trace").
///
/// The analysis runs on its own thread off a [`LiveSource`] merge;
/// `on_refresh` receives interim snapshots from sinks that implement
/// [`AnalysisSink::refresh`], every `live.refresh` period. The traced
/// application is never blocked by analysis: full channels drop and
/// count (see [`LiveRunReport::total_dropped`]).
pub fn run_live(
    node: &Arc<Node>,
    workload: &dyn Workload,
    config: &IprofConfig,
    live_cfg: &LiveConfig,
    mut sinks: Vec<Box<dyn AnalysisSink + Send>>,
    on_refresh: impl FnMut(&str) + Send,
) -> LiveRunReport {
    assert!(config.tracing, "live mode requires tracing");
    let hub = LiveHub::new(&node.config.hostname, live_cfg.channel_depth, live_cfg.retain);
    let session = install_session(SessionConfig {
        mode: config.mode,
        buffer_capacity: config.buffer_capacity,
        sink: SinkKind::Live(hub.clone()),
        selected_ranks: config.selected_ranks.clone(),
        hostname: node.config.hostname.clone(),
        consumer_interval: Duration::from_millis(2),
    });
    for p in &config.disabled_patterns {
        session.disable_matching(p);
    }
    let sampler = config
        .sampling
        .clone()
        .map(|s| Sampler::start(node.clone(), s));

    let source = LiveSource::new(hub.clone());
    let refresh = live_cfg.refresh;
    let (pipe, wall) = std::thread::scope(|scope| {
        let analysis = scope.spawn(move || {
            live::run_live_pipeline(source, &mut sinks, refresh, on_refresh)
        });
        let t0 = Instant::now();
        // A panicking workload must still tear the session down (final
        // drain + hub close), or the analysis thread would wait forever
        // and the scope would hang instead of propagating the panic.
        let run_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            workload.run(node);
            node.synchronize();
        }));
        let wall = t0.elapsed();
        if let Some(s) = sampler {
            s.stop();
        }
        // Stops the consumer: final drain, then hub close — which is what
        // terminates the analysis thread's merge.
        uninstall_session().expect("session vanished");
        let pipe = analysis.join().expect("live analysis thread panicked");
        if let Err(p) = run_result {
            std::panic::resume_unwind(p);
        }
        (pipe, wall)
    });

    let stats = session.stats();
    let trace = live_cfg.retain.then(|| {
        btf::collect(&session, &[("app".to_string(), workload.name().to_string())])
    });
    LiveRunReport {
        app: workload.name().to_string(),
        config: config.label(),
        wall,
        stats,
        trace,
        live: hub.stats(),
        reports: pipe.reports,
        latency: pipe.latency,
    }
}

/// Result of one `iprof serve --live` run: the live run fields plus what
/// the publisher relayed over the wire.
#[derive(Debug)]
pub struct ServeReport {
    /// Workload name.
    pub app: String,
    /// Configuration label.
    pub config: String,
    /// Application wall time.
    pub wall: Duration,
    /// Tracer statistics (ring-level written/dropped).
    pub stats: SessionStats,
    /// The collected trace — only with [`LiveConfig::retain`] (used by
    /// the remote equivalence tests), `None` in production serve mode.
    pub trace: Option<TraceData>,
    /// Channel-level statistics: received/dropped/beacons.
    pub live: LiveStats,
    /// Wire-level statistics: frames/events/beacons/bytes relayed —
    /// cumulative across every connection for a resumable serve.
    pub publish: PublishStats,
    /// One entry per subscriber connection that ended before Eos, with
    /// the reason (always empty for the one-shot [`run_serve`]). A
    /// resumable serve kept going after each of these.
    pub disconnects: Vec<String>,
    /// Per-subscriber accounting rows, in registration order (nonempty
    /// only for [`run_serve_broadcast`]): wire version, events
    /// forwarded/lagged, demotions and disconnects per connection.
    pub subscribers: Vec<SubscriberStats>,
}

impl ServeReport {
    /// Total events lost to backpressure anywhere on the serve path
    /// (ring discard + channel drop — a stalled subscriber shows up
    /// here, never as application time). Zero means the subscriber saw
    /// exactly what a local `--live` run would have.
    pub fn total_dropped(&self) -> u64 {
        self.stats.dropped.saturating_add(self.live.dropped)
    }
}

/// Run `workload` under `config` and **publish** the live channels over
/// `conn` instead of analyzing locally: the session's consumer feeds the
/// hub exactly as in [`run_live`], and a publisher thread tees every
/// event/beacon/close into THRL frames ([`crate::remote`]) for a remote
/// `iprof attach` to merge and analyze.
///
/// Blocks until the workload finishes and the wire drains. Transport
/// failures tear nothing down on the traced side — the session completes
/// and the error is returned after teardown.
///
/// `wire` selects the THRL version the publisher speaks (`--wire`):
/// 3 (default) batches events, 2 keeps the frozen per-event stream for
/// v2-only subscribers — the subscriber hard-rejects versions it does
/// not speak, so the downgrade is always publisher-selected.
///
/// `telemetry` selects self-telemetry exposures (`--telemetry`,
/// `--telemetry-json`) over the hub's registry for the duration of the
/// run; pass `&TelemetryOptions::default()` to expose nothing.
pub fn run_serve<W: Write + Send>(
    node: &Arc<Node>,
    workload: &dyn Workload,
    config: &IprofConfig,
    live_cfg: &LiveConfig,
    conn: W,
    wire: u32,
    telemetry: &TelemetryOptions,
) -> std::io::Result<ServeReport> {
    assert!(config.tracing, "serve mode requires tracing");
    let hub = LiveHub::new(&node.config.hostname, live_cfg.channel_depth, live_cfg.retain);
    // before the session installs: a failed bind must not leave a
    // half-launched run behind
    let exposure = TelemetryExposure::start(telemetry, hub.telemetry())?;
    let session = install_session(SessionConfig {
        mode: config.mode,
        buffer_capacity: config.buffer_capacity,
        sink: SinkKind::Live(hub.clone()),
        selected_ranks: config.selected_ranks.clone(),
        hostname: node.config.hostname.clone(),
        consumer_interval: Duration::from_millis(2),
    });
    for p in &config.disabled_patterns {
        session.disable_matching(p);
    }
    let sampler = config
        .sampling
        .clone()
        .map(|s| Sampler::start(node.clone(), s));

    let (published, wall) = std::thread::scope(|scope| {
        let hub_ref = &hub;
        let publisher = scope.spawn(move || remote::publish_with(hub_ref, conn, wire));
        let t0 = Instant::now();
        // Same teardown discipline as run_live: a panicking workload must
        // still uninstall (final drain + hub close) so the publisher's
        // batch loop terminates and the scope can propagate the panic.
        let run_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            workload.run(node);
            node.synchronize();
        }));
        let wall = t0.elapsed();
        if let Some(s) = sampler {
            s.stop();
        }
        uninstall_session().expect("session vanished");
        let published = publisher.join().expect("publisher thread panicked");
        if let Err(p) = run_result {
            std::panic::resume_unwind(p);
        }
        (published, wall)
    });

    let stats = session.stats();
    let trace = live_cfg.retain.then(|| {
        btf::collect(&session, &[("app".to_string(), workload.name().to_string())])
    });
    // threads have joined: the registry is settled, so the exposure's
    // final JSON snapshot carries exactly the numbers reported below
    exposure.finish();
    Ok(ServeReport {
        app: workload.name().to_string(),
        config: config.label(),
        wall,
        stats,
        trace,
        live: hub.stats(),
        publish: published?,
        disconnects: Vec::new(),
        subscribers: Vec::new(),
    })
}

/// Run `workload` and publish its live channels as a **resumable**
/// session (`iprof serve --resume-buffer <bytes>`): the publisher owns a
/// session epoch and a byte-budgeted replay ring, `accept` supplies
/// subscriber connections, and a dropped subscriber can reconnect and
/// resume from its per-stream cursors without losing anything the ring
/// still holds (`docs/PROTOCOL.md` § Session resumption). Publishing
/// ends only at a clean Eos on the wire.
///
/// `accept` supplies subscriber connections: `Ok(Some(conn))` serves
/// it, `Ok(None)` means "no subscriber right now" — the publisher then
/// drains pending hub progress into the replay ring and polls again, so
/// `accept` should sleep briefly before returning `None` (the CLI polls
/// a nonblocking listener at ~20 ms). An `Err` from it is fatal to the
/// *publishing* side only — the traced run still completes and is
/// reported, with the error returned here after teardown.
pub fn run_serve_resumable<S, A>(
    node: &Arc<Node>,
    workload: &dyn Workload,
    config: &IprofConfig,
    live_cfg: &LiveConfig,
    mut accept: A,
    resume_buffer: usize,
    wire: u32,
    telemetry: &TelemetryOptions,
) -> std::io::Result<ServeReport>
where
    S: Read + Write + Send,
    A: FnMut() -> std::io::Result<Option<S>> + Send,
{
    assert!(config.tracing, "serve mode requires tracing");
    let hub = LiveHub::new(&node.config.hostname, live_cfg.channel_depth, live_cfg.retain);
    let exposure = TelemetryExposure::start(telemetry, hub.telemetry())?;
    let session = install_session(SessionConfig {
        mode: config.mode,
        buffer_capacity: config.buffer_capacity,
        sink: SinkKind::Live(hub.clone()),
        selected_ranks: config.selected_ranks.clone(),
        hostname: node.config.hostname.clone(),
        consumer_interval: Duration::from_millis(2),
    });
    for p in &config.disabled_patterns {
        session.disable_matching(p);
    }
    let sampler = config
        .sampling
        .clone()
        .map(|s| Sampler::start(node.clone(), s));

    let pub_hub = hub.clone();
    let (published, wall) = std::thread::scope(|scope| {
        let publisher_thread = scope.spawn(move || {
            let mut publisher =
                Publisher::new(pub_hub, Publisher::fresh_epoch(), resume_buffer).with_wire(wire);
            let mut disconnects = Vec::new();
            loop {
                match accept()? {
                    Some(conn) => match publisher.serve_connection(conn) {
                        ServeOutcome::Complete => {
                            return Ok((publisher.stats(), disconnects));
                        }
                        ServeOutcome::Lost(reason) => disconnects.push(reason),
                    },
                    // nobody attached: keep hub → ring so the outage
                    // costs ring budget, not events
                    None => publisher.drain_to_ring(),
                }
            }
        });
        let t0 = Instant::now();
        // Same teardown discipline as run_serve: a panicking workload
        // must still uninstall (final drain + hub close). The publisher
        // keeps serving until the wire reaches Eos — between subscriber
        // connections the hub drains into the replay ring, so nothing
        // is lost while no one is attached.
        let run_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            workload.run(node);
            node.synchronize();
        }));
        let wall = t0.elapsed();
        if let Some(s) = sampler {
            s.stop();
        }
        uninstall_session().expect("session vanished");
        let published = publisher_thread.join().expect("publisher thread panicked");
        if let Err(p) = run_result {
            std::panic::resume_unwind(p);
        }
        (published, wall)
    });

    let stats = session.stats();
    let trace = live_cfg.retain.then(|| {
        btf::collect(&session, &[("app".to_string(), workload.name().to_string())])
    });
    exposure.finish();
    let (publish, disconnects) = published?;
    Ok(ServeReport {
        app: workload.name().to_string(),
        config: config.label(),
        wall,
        stats,
        trace,
        live: hub.stats(),
        publish,
        disconnects,
        subscribers: Vec::new(),
    })
}

/// Run `workload` and **broadcast** its live channels to N concurrent
/// subscribers (`iprof serve --subscribers <n>`): one [`Broadcaster`]
/// pump mirrors the hub into a shared replay ring, and every accepted
/// connection is served on its own thread with independent per-stream
/// cursors, wire negotiation and batch dictionary
/// (`docs/PROTOCOL.md` § Broadcast). On the wire each connection is an
/// ordinary resumable THRL session — broadcast is invisible to
/// subscribers.
///
/// `accept` has the same contract as in [`run_serve_resumable`]:
/// `Ok(None)` means "no subscriber right now" (sleep briefly before
/// returning it). Accepting continues past `subscribers` connections —
/// a viewer that dropped can dial back in as a fresh slot — and the
/// serve ends once at least `subscribers` connections were accepted,
/// the workload's stream reached Eos, and every serve thread finished.
///
/// `resume_buffer` bounds the shared ring; `max_lag` is the
/// per-subscriber lag budget (`--max-lag`): a subscriber more than
/// `max_lag` bytes behind is demoted to gap delivery when the ring is
/// over budget, instead of pinning memory for everyone. `None` never
/// demotes — the ring then grows past its budget rather than evict an
/// entitled laggard.
#[allow(clippy::too_many_arguments)]
pub fn run_serve_broadcast<S, A>(
    node: &Arc<Node>,
    workload: &dyn Workload,
    config: &IprofConfig,
    live_cfg: &LiveConfig,
    mut accept: A,
    subscribers: usize,
    resume_buffer: usize,
    max_lag: Option<usize>,
    wire: u32,
    telemetry: &TelemetryOptions,
) -> std::io::Result<ServeReport>
where
    S: Read + Write + Send,
    A: FnMut() -> std::io::Result<Option<S>> + Send,
{
    assert!(config.tracing, "serve mode requires tracing");
    assert!(subscribers >= 1, "broadcast needs at least one subscriber");
    let hub = LiveHub::new(&node.config.hostname, live_cfg.channel_depth, live_cfg.retain);
    let exposure = TelemetryExposure::start(telemetry, hub.telemetry())?;
    let session = install_session(SessionConfig {
        mode: config.mode,
        buffer_capacity: config.buffer_capacity,
        sink: SinkKind::Live(hub.clone()),
        selected_ranks: config.selected_ranks.clone(),
        hostname: node.config.hostname.clone(),
        consumer_interval: Duration::from_millis(2),
    });
    for p in &config.disabled_patterns {
        session.disable_matching(p);
    }
    let sampler = config
        .sampling
        .clone()
        .map(|s| Sampler::start(node.clone(), s));

    let mut bc = Broadcaster::new(hub.clone(), Publisher::fresh_epoch(), resume_buffer);
    if let Some(lag) = max_lag {
        bc = bc.with_max_lag(lag);
    }
    let bc = &bc;
    let (served, wall) = std::thread::scope(|scope| {
        // One pump owns hub → ring; it exits when the hub closes and
        // drains, which is what lets every serve thread reach Eos.
        scope.spawn(move || bc.pump());
        let manager = scope.spawn(move || {
            let mut handles: Vec<std::thread::ScopedJoinHandle<'_, ServeOutcome>> = Vec::new();
            let mut accepted = 0usize;
            loop {
                if accepted >= subscribers
                    && bc.finished()
                    && handles.iter().all(|h| h.is_finished())
                {
                    break;
                }
                if let Some(conn) = accept()? {
                    accepted += 1;
                    handles.push(scope.spawn(move || bc.serve_connection(conn, wire)));
                }
            }
            let mut disconnects = Vec::new();
            for h in handles {
                if let ServeOutcome::Lost(reason) =
                    h.join().expect("broadcast serve thread panicked")
                {
                    disconnects.push(reason);
                }
            }
            Ok::<Vec<String>, std::io::Error>(disconnects)
        });
        let t0 = Instant::now();
        // Same teardown discipline as run_serve_resumable: a panicking
        // workload must still uninstall (final drain + hub close) so the
        // pump terminates, Eos reaches every subscriber, and the scope
        // can propagate the panic.
        let run_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            workload.run(node);
            node.synchronize();
        }));
        let wall = t0.elapsed();
        if let Some(s) = sampler {
            s.stop();
        }
        uninstall_session().expect("session vanished");
        let served = manager.join().expect("broadcast manager thread panicked");
        if let Err(p) = run_result {
            std::panic::resume_unwind(p);
        }
        (served, wall)
    });

    let stats = session.stats();
    let trace = live_cfg.retain.then(|| {
        btf::collect(&session, &[("app".to_string(), workload.name().to_string())])
    });
    exposure.finish();
    let disconnects = served?;
    Ok(ServeReport {
        app: workload.name().to_string(),
        config: config.label(),
        wall,
        stats,
        trace,
        live: hub.stats(),
        publish: bc.stats(),
        disconnects,
        subscribers: bc.subscriber_stats(),
    })
}

/// Result of one `iprof attach` run.
#[derive(Debug)]
pub struct AttachReport {
    /// Hostname announced by the publisher.
    pub hostname: String,
    /// One final report per sink, in sink order — same contract as
    /// [`run_live`], produced from the remote stream.
    pub reports: Vec<AnalysisReport>,
    /// Merge latency over the mirror hub (staleness as seen here).
    pub latency: LatencySummary,
    /// Mirror-hub statistics (received == events merged; never drops,
    /// the attach feed is lossless).
    pub local: LiveStats,
    /// Connection statistics, including the publisher's drop totals —
    /// the remote half of the drop accounting. If the publisher died
    /// before a clean Eos, [`RemoteStats::error`] is set and the
    /// reports above cover everything received up to the cut (partial
    /// analysis of a dying app is preserved, not discarded).
    pub remote: RemoteStats,
}

/// Attach to a remote publisher over `conn` and drive `sinks` on-line
/// from its stream: handshake, mirror the hub, run the **unmodified**
/// [`LiveSource`] merge through [`live::run_live_pipeline`] with
/// optional periodic refresh — the receiving half of `iprof serve`.
/// The single-connection special case of [`run_fanin`].
///
/// For a lossless feed (`remote.server_dropped == 0`) the reports are
/// byte-identical to a local `iprof --live` of the same run.
pub fn run_attach<R: Read + Send + 'static>(
    conn: R,
    depth: usize,
    sinks: Vec<Box<dyn AnalysisSink>>,
    refresh: Option<Duration>,
    on_refresh: impl FnMut(&str),
) -> std::io::Result<AttachReport> {
    let mut r =
        run_fanin(vec![conn], depth, sinks, refresh, on_refresh, &TelemetryOptions::default())?;
    Ok(AttachReport {
        hostname: r.hostnames.swap_remove(0),
        reports: r.reports,
        latency: r.latency,
        local: r.local,
        remote: r.stats.per.swap_remove(0),
    })
}

/// Result of one multi-publisher `iprof attach <addr> <addr>...` run.
#[derive(Debug)]
pub struct FanInReport {
    /// Hostname announced by each publisher, in connection order.
    pub hostnames: Vec<String>,
    /// One final report per sink, in sink order — same contract as
    /// [`run_live`], produced from the merged union of every
    /// publisher's streams.
    pub reports: Vec<AnalysisReport>,
    /// Merge latency over the shared mirror hub.
    pub latency: LatencySummary,
    /// Shared mirror-hub statistics over the whole union.
    pub local: LiveStats,
    /// Per-origin accounting (channels, events merged, publisher-side
    /// drops), in connection order.
    pub origins: Vec<OriginStats>,
    /// Per-connection statistics, in connection order
    /// ([`FanInStats::per`]). A publisher that died before its Eos keeps
    /// its partial accounting there with [`RemoteStats::error`] set —
    /// the reports above then cover everything received from it before
    /// the cut, plus everything from every surviving publisher.
    pub stats: FanInStats,
}

impl FanInReport {
    /// Sum of publisher-side accepted totals (saturating).
    pub fn server_received(&self) -> u64 {
        self.stats.server_received()
    }

    /// Sum of publisher-side dropped totals from clean Eos frames
    /// (saturating). Zero means every publisher *certified* losslessness.
    pub fn server_dropped(&self) -> u64 {
        self.stats.server_dropped()
    }

    /// Publishers that ended without a clean Eos.
    pub fn failed_publishers(&self) -> usize {
        self.stats.failed()
    }

    /// Best known publisher-side loss (saturating): the sum of
    /// [`OriginStats::known_dropped`] over every origin — per
    /// publisher, the larger of its self-reported Eos total and our own
    /// receiver-side ledger sum (cumulative `Drops` + resume gaps).
    /// The ledgers are disjoint by construction so their sum never
    /// counts an event twice, and the opaque Eos total *competes*
    /// against that sum instead of stacking a gap on top of a drop it
    /// may already include — a publisher that reported drops and then
    /// died before Eos still counts as lossy, and a resumed-with-gap
    /// session can never pass as lossless (`--live-strict` gates on
    /// this, not on [`FanInReport::server_dropped`] alone).
    pub fn known_dropped(&self) -> u64 {
        self.origins.iter().fold(0u64, |a, o| a.saturating_add(o.known_dropped()))
    }

    /// Successful session resumes across every publisher connection.
    pub fn reconnects(&self) -> u64 {
        self.stats.reconnects()
    }

    /// Events lost to resume gaps across every publisher (saturating).
    pub fn resume_gaps(&self) -> u64 {
        self.stats.resume_gaps()
    }
}

/// Attach to N remote publishers and drive `sinks` on-line from the
/// merged union of all their streams: handshake every connection,
/// namespace each publisher's stream ids into one shared mirror hub
/// ([`FanIn`]), and run the **unmodified** [`LiveSource`] merge through
/// [`live::run_live_pipeline`] — fleet-scale `iprof attach`.
///
/// For lossless feeds the reports are byte-identical to a single local
/// `--live` run over the concatenated stream set. One dying publisher
/// only ends its own streams: the analysis completes over the rest and
/// the failure is recorded in that publisher's [`RemoteStats`].
pub fn run_fanin<R: Read + Send + 'static>(
    conns: Vec<R>,
    depth: usize,
    sinks: Vec<Box<dyn AnalysisSink>>,
    refresh: Option<Duration>,
    on_refresh: impl FnMut(&str),
    telemetry: &TelemetryOptions,
) -> std::io::Result<FanInReport> {
    drive_fanin(FanIn::open(conns, depth)?, sinks, refresh, on_refresh, telemetry)
}

/// [`run_fanin`] with reconnect/resume: every connection comes from a
/// redialable `connector`, and a dropped connection to a resumable
/// publisher (`iprof serve --resume-buffer`) is resumed under `policy`
/// — the reader redials with backoff, re-handshakes, validates the
/// session epoch and continues from its per-stream cursors, replaying
/// the lost tail from the publisher's ring. With no gaps the reports
/// are byte-identical to an uninterrupted run; ring-evicted events land
/// in [`FanInReport::known_dropped`] (and fail `--live-strict`) instead
/// of tearing the feed down.
pub fn run_fanin_resumable<S, C>(
    connectors: Vec<C>,
    depth: usize,
    policy: ReconnectPolicy,
    sinks: Vec<Box<dyn AnalysisSink>>,
    refresh: Option<Duration>,
    on_refresh: impl FnMut(&str),
    telemetry: &TelemetryOptions,
) -> std::io::Result<FanInReport>
where
    S: Read + Write + Send + 'static,
    C: FnMut() -> std::io::Result<S> + Send + 'static,
{
    drive_fanin(
        FanIn::open_resumable(connectors, depth, policy)?,
        sinks,
        refresh,
        on_refresh,
        telemetry,
    )
}

/// Shared tail of [`run_fanin`] / [`run_fanin_resumable`]: drive the
/// unmodified merge + sinks over the opened fan-in and gather every
/// accounting surface.
fn drive_fanin(
    fan: FanIn,
    mut sinks: Vec<Box<dyn AnalysisSink>>,
    refresh: Option<Duration>,
    on_refresh: impl FnMut(&str),
    telemetry: &TelemetryOptions,
) -> std::io::Result<FanInReport> {
    let exposure = TelemetryExposure::start(telemetry, fan.hub().telemetry())?;
    let hostnames = fan.hostnames.clone();
    let pipe = live::run_live_pipeline(fan.source(), &mut sinks, refresh, on_refresh);
    let local = fan.hub().stats();
    let origins = fan.hub().origin_stats();
    let stats = fan.finish()?;
    // readers joined in finish(): the final JSON snapshot carries the
    // settled numbers the report below is built from
    exposure.finish();
    Ok(FanInReport {
        hostnames,
        reports: pipe.reports,
        latency: pipe.latency,
        local,
        origins,
        stats,
    })
}

/// Result of one `iprof relay` run.
#[derive(Debug)]
pub struct RelayReport {
    /// The relay's own identity: its mirror hub's label, announced in
    /// every upstream Hello (`--label`, defaulting to the first
    /// downstream publisher's hostname).
    pub label: String,
    /// Hostname announced by each downstream publisher, in connection
    /// order.
    pub hostnames: Vec<String>,
    /// Mirror-hub statistics over the merged union this relay carried.
    pub local: LiveStats,
    /// Per-downstream accounting (channels, events merged, drops/eos/
    /// resume-gap ledgers — including sub-origins relayed through
    /// deeper levels), in connection order.
    pub origins: Vec<OriginStats>,
    /// Per-downstream connection statistics ([`FanInStats::per`]).
    pub downstream: FanInStats,
    /// Aggregate upstream wire statistics across every subscriber
    /// served.
    pub publish: PublishStats,
    /// Upstream connections that ended before Eos, with reasons; the
    /// relay kept serving after each (a dropped parent resumes as a
    /// fresh slot).
    pub disconnects: Vec<String>,
    /// Per-upstream-subscriber accounting rows, in accept order.
    pub subscribers: Vec<SubscriberStats>,
}

impl RelayReport {
    /// Best known downstream loss (saturating): the sum of
    /// [`OriginStats::known_dropped`] over every downstream origin —
    /// the same disjoint-ledger fold [`FanInReport::known_dropped`]
    /// applies at an attach. The conservation law a healthy relay
    /// satisfies, and the chaos testkit's oracle checks, is
    /// `local.received + known_dropped() == events published at the
    /// leaves below this relay` — loss booked at a leaf (its Eos
    /// deficit), on a downstream hop (resume gap) or at a deeper relay
    /// (child ledgers) counts exactly once.
    pub fn known_dropped(&self) -> u64 {
        self.origins.iter().fold(0u64, |a, o| a.saturating_add(o.known_dropped()))
    }
}

/// Run one hierarchical relay node (`iprof relay <listen-addr>
/// <addr>...`): a [`FanIn`] subscriber draining N downstream publishers
/// into one mirror hub, re-published upstream by a [`Broadcaster`] in
/// origin-relay mode — simultaneously the receiving half of `iprof
/// attach` and the serving half of `iprof serve --subscribers`, glued
/// by the shared [`crate::remote::HubPump`] with **no merge in
/// between**: forward batches keep the hub's global channel order, so
/// the root's k-way merge over a relay sees exactly the concatenated
/// order a flat N-way attach would (byte-identity, module property 8 in
/// [`crate::remote`]). Per-leaf identity rides [`crate::remote::Frame::Origin`]
/// entries with hierarchical path ids, so drop/eos/gap accounting and
/// telemetry series survive aggregation per leaf.
///
/// `connectors` dial the downstream publishers (resumable under
/// `policy`, exactly like [`run_fanin_resumable`]); `accept` supplies
/// upstream subscriber connections with the [`run_serve_broadcast`]
/// contract (`Ok(None)` = nobody right now, sleep briefly first). The
/// relay ends once every downstream reached Eos (the fan-in seals the
/// hub), at least `subscribers` upstream connections were accepted, and
/// every upstream serve finished. Relaying requires the v3 wire —
/// [`crate::remote::Frame::Origin`] does not exist on v2.
#[allow(clippy::too_many_arguments)]
pub fn run_relay<S, C, U, A>(
    connectors: Vec<C>,
    depth: usize,
    policy: ReconnectPolicy,
    label: Option<&str>,
    accept: A,
    subscribers: usize,
    resume_buffer: usize,
    max_lag: Option<usize>,
    telemetry: &TelemetryOptions,
) -> std::io::Result<RelayReport>
where
    S: Read + Write + Send + 'static,
    C: FnMut() -> std::io::Result<S> + Send + 'static,
    U: Read + Write + Send,
    A: FnMut() -> std::io::Result<Option<U>> + Send,
{
    assert!(subscribers >= 1, "relay needs at least one upstream subscriber");
    let fan = FanIn::open_resumable_labeled(connectors, depth, policy, label)?;
    let hub = fan.hub().clone();
    let exposure = TelemetryExposure::start(telemetry, hub.telemetry())?;
    let mut bc = Broadcaster::new(hub.clone(), Publisher::fresh_epoch(), resume_buffer)
        .with_origin_relay();
    if let Some(lag) = max_lag {
        bc = bc.with_max_lag(lag);
    }
    let bc = &bc;
    let served = std::thread::scope(|scope| {
        // One pump owns hub → shared ring (the same HubPump the other
        // publishers use); it exits when the last fan-in reader seals
        // the hub, which is what lets every upstream serve reach Eos.
        scope.spawn(move || bc.pump());
        let manager = scope.spawn(move || {
            let mut accept = accept;
            let mut handles: Vec<std::thread::ScopedJoinHandle<'_, ServeOutcome>> = Vec::new();
            let mut accepted = 0usize;
            loop {
                if accepted >= subscribers
                    && bc.finished()
                    && handles.iter().all(|h| h.is_finished())
                {
                    break;
                }
                if let Some(conn) = accept()? {
                    accepted += 1;
                    // v3 only: Origin frames do not exist on a v2 wire
                    handles.push(scope.spawn(move || bc.serve_connection(conn, 3)));
                }
            }
            let mut disconnects = Vec::new();
            for h in handles {
                if let ServeOutcome::Lost(reason) =
                    h.join().expect("relay serve thread panicked")
                {
                    disconnects.push(reason);
                }
            }
            Ok::<Vec<String>, std::io::Error>(disconnects)
        });
        manager.join().expect("relay manager thread panicked")
    });
    let local = hub.stats();
    let origins = hub.origin_stats();
    let hostnames = fan.hostnames.clone();
    let downstream = fan.finish()?;
    // readers and serves joined: the registry is settled, so the final
    // JSON snapshot carries exactly the numbers reported below
    exposure.finish();
    let disconnects = served?;
    Ok(RelayReport {
        label: hub.hostname().to_string(),
        hostnames,
        local,
        origins,
        downstream,
        publish: bc.stats(),
        disconnects,
        subscribers: bc.subscriber_stats(),
    })
}

/// Run baseline + each config, with one warmup baseline run first (primes
/// PJRT compile caches so module-create cost doesn't skew a single cell).
/// Returns reports in the same order as `configs`, prefixed by baseline.
pub fn run_matrix(
    node: &Arc<Node>,
    workload: &dyn Workload,
    configs: &[IprofConfig],
) -> Vec<RunReport> {
    // warmup (not reported)
    let _ = run(node, workload, &IprofConfig::baseline());
    let mut reports = vec![run(node, workload, &IprofConfig::baseline())];
    for c in configs {
        reports.push(run(node, workload, c));
    }
    reports
}

/// Percentage overhead of `traced` relative to `base`.
pub fn overhead_pct(base: Duration, traced: Duration) -> f64 {
    if base.as_nanos() == 0 {
        return 0.0;
    }
    (traced.as_secs_f64() - base.as_secs_f64()) / base.as_secs_f64() * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::hecbench;
    use crate::device::NodeConfig;
    use crate::tracer::session::test_support;

    #[test]
    fn baseline_run_has_no_stats() {
        let _g = test_support::lock();
        let node = Node::new(NodeConfig::test_small());
        let apps = hecbench::suite();
        let app = apps.iter().find(|a| a.name() == "saxpy-ze").unwrap();
        let r = run(&node, app.as_ref(), &IprofConfig::baseline());
        assert!(r.stats.is_none());
        assert!(r.trace.is_none());
        assert!(r.wall > Duration::ZERO);
    }

    #[test]
    fn traced_run_produces_trace_and_tally() {
        let _g = test_support::lock();
        let node = Node::new(NodeConfig::test_small());
        let apps = hecbench::suite();
        let app = apps.iter().find(|a| a.name() == "saxpy-ze").unwrap();
        let r = run(&node, app.as_ref(), &IprofConfig::default());
        let stats = r.stats.as_ref().unwrap();
        assert!(stats.written > 50, "saxpy-ze wrote {} events", stats.written);
        let tally = r.tally().unwrap();
        assert!(tally.host.keys().any(|(api, _)| api == "ZE"));
        assert!(!tally.device.is_empty(), "device rows from profiling events");
    }

    #[test]
    fn analyze_drives_multiple_sinks_in_one_pass() {
        let _g = test_support::lock();
        let node = Node::new(NodeConfig::test_small());
        let apps = hecbench::suite();
        let app = apps.iter().find(|a| a.name() == "saxpy-ze").unwrap();
        let r = run(&node, app.as_ref(), &IprofConfig::default());
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![
            Box::new(crate::analysis::TallySink::new()),
            Box::new(crate::analysis::TimelineSink::new()),
        ];
        let reports = r.analyze(&mut sinks).unwrap().unwrap();
        assert_eq!(reports.len(), 2);
        assert!(reports[0].payload().unwrap().contains("Time(%)"));
        assert!(reports[1].payload().unwrap().contains("traceEvents"));
        // baseline has no trace -> None
        let base = run(&node, app.as_ref(), &IprofConfig::baseline());
        assert!(base.analyze(&mut sinks).is_none());
    }

    #[test]
    fn live_run_reports_match_postmortem_over_identical_trace() {
        let _g = test_support::lock();
        std::env::set_var("THAPI_APP_SCALE", "0.1");
        let node = Node::new(NodeConfig::test_small());
        let apps = hecbench::suite();
        let app = apps.iter().find(|a| a.name() == "saxpy-ze").unwrap();
        // deep channels (no drops) + retain so the same run feeds both paths
        let live_cfg = LiveConfig { channel_depth: 1 << 16, retain: true, refresh: None };
        let sinks: Vec<Box<dyn AnalysisSink + Send>> =
            vec![Box::new(crate::analysis::TallySink::new())];
        let r = run_live(&node, app.as_ref(), &IprofConfig::default(), &live_cfg, sinks, |_| {});
        assert_eq!(r.live.dropped, 0, "deep channels must not drop");
        assert!(r.live.received > 50, "live path received {}", r.live.received);
        assert_eq!(r.reports.len(), 1);

        let parsed = analysis::parse_trace(r.trace.as_ref().unwrap()).unwrap();
        let mut pm: Vec<Box<dyn AnalysisSink>> =
            vec![Box::new(crate::analysis::TallySink::new())];
        let pm_reports = analysis::run_pipeline(&parsed, &mut pm);
        assert_eq!(
            r.reports[0].payload(),
            pm_reports[0].payload(),
            "on-line tally must be byte-identical to post-mortem"
        );
    }

    #[test]
    fn config_labels_match_paper() {
        assert_eq!(IprofConfig::baseline().label(), "base");
        assert_eq!(IprofConfig::paper_config(TracingMode::Default, false).label(), "T-default");
        assert_eq!(IprofConfig::paper_config(TracingMode::Minimal, true).label(), "TS-min");
        assert_eq!(IprofConfig::paper_config(TracingMode::Full, true).label(), "TS-full");
    }

    #[test]
    fn overhead_pct_math() {
        assert!((overhead_pct(Duration::from_secs(1), Duration::from_millis(1050)) - 5.0).abs() < 1e-9);
        assert_eq!(overhead_pct(Duration::ZERO, Duration::from_secs(1)), 0.0);
    }
}
