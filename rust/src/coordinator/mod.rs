//! The `iprof` coordinator: session lifecycle + workload execution +
//! post-mortem analysis dispatch (paper §3.4 "Tracing begins by launching
//! the application using the iprof launcher").
//!
//! [`IprofConfig`] mirrors the paper's launcher knobs: tracing mode
//! (minimal/default/full), device sampling on/off (+ interval), event
//! filtering, rank selection, trace-vs-aggregate persistence. [`run`]
//! executes one workload under one configuration and returns a
//! [`RunReport`] with wall time, tracer statistics and the requested
//! analyses — the building block of every §5 experiment.

use crate::analysis::{self, AnalysisSink, Report as AnalysisReport, Tally};
use anyhow::Result;
use crate::apps::Workload;
use crate::device::Node;
use crate::sampling::{Sampler, SamplingConfig};
use crate::tracer::btf::{self, TraceData};
use crate::tracer::{
    install_session, uninstall_session, SessionConfig, SessionStats, SinkKind, TracingMode,
};
use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Launcher configuration (the `iprof` CLI surface).
#[derive(Debug, Clone)]
pub struct IprofConfig {
    /// Tracing enabled at all (false = baseline run).
    pub tracing: bool,
    /// Tracing mode.
    pub mode: TracingMode,
    /// Device sampling daemon (TS-* configurations).
    pub sampling: Option<SamplingConfig>,
    /// Trace sink.
    pub sink: SinkKind,
    /// Rank selection (None = all ranks).
    pub selected_ranks: Option<HashSet<u32>>,
    /// Event-name substring filters to disable.
    pub disabled_patterns: Vec<String>,
    /// Ring-buffer capacity per thread.
    pub buffer_capacity: usize,
}

impl Default for IprofConfig {
    fn default() -> Self {
        IprofConfig {
            tracing: true,
            mode: TracingMode::Default,
            sampling: None,
            sink: SinkKind::Memory,
            selected_ranks: None,
            disabled_patterns: Vec::new(),
            buffer_capacity: 8 << 20,
        }
    }
}

impl IprofConfig {
    /// Baseline (untraced) run.
    pub fn baseline() -> Self {
        IprofConfig { tracing: false, ..Default::default() }
    }

    /// One of the six §5.2 configurations: T-{min,default,full} and
    /// TS-{min,default,full}.
    pub fn paper_config(mode: TracingMode, sampling: bool) -> Self {
        IprofConfig {
            tracing: true,
            mode,
            sampling: if sampling { Some(SamplingConfig::default()) } else { None },
            ..Default::default()
        }
    }

    /// Label like "T-default" / "TS-min" (baseline: "base").
    pub fn label(&self) -> String {
        if !self.tracing {
            return "base".into();
        }
        let prefix = if self.sampling.is_some() { "TS" } else { "T" };
        format!("{prefix}-{}", self.mode.label())
    }
}

/// Result of one `iprof` run.
#[derive(Debug)]
pub struct RunReport {
    /// Workload name.
    pub app: String,
    /// Configuration label.
    pub config: String,
    /// Application wall time.
    pub wall: Duration,
    /// Tracer statistics (None for baseline).
    pub stats: Option<SessionStats>,
    /// The collected trace (None for baseline / Null sink).
    pub trace: Option<TraceData>,
}

impl RunReport {
    /// Trace size in bytes (0 if none).
    pub fn trace_bytes(&self) -> u64 {
        self.trace.as_ref().map(|t| t.size_bytes()).unwrap_or(0)
    }

    /// Run the tally analysis over the collected trace in one streaming
    /// pass (lazy muxing + incremental interval pairing — no
    /// materialized `Vec<EventMsg>`).
    pub fn tally(&self) -> Option<Tally> {
        let trace = self.trace.as_ref()?;
        let parsed = analysis::parse_trace(trace).ok()?;
        Some(Tally::from_parsed(&parsed))
    }

    /// Drive an arbitrary set of analysis sinks from one streaming pass
    /// over the collected trace. Returns `None` for baseline runs
    /// (no trace), one [`AnalysisReport`] per sink otherwise.
    pub fn analyze(
        &self,
        sinks: &mut [Box<dyn AnalysisSink + '_>],
    ) -> Option<Result<Vec<AnalysisReport>>> {
        let trace = self.trace.as_ref()?;
        Some(analysis::parse_trace(trace).map(|parsed| analysis::run_pipeline(&parsed, sinks)))
    }
}

/// Run `workload` on `node` under `config`.
pub fn run(node: &Arc<Node>, workload: &dyn Workload, config: &IprofConfig) -> RunReport {
    if !config.tracing {
        let t0 = Instant::now();
        workload.run(node);
        node.synchronize();
        return RunReport {
            app: workload.name().to_string(),
            config: config.label(),
            wall: t0.elapsed(),
            stats: None,
            trace: None,
        };
    }

    let session = install_session(SessionConfig {
        mode: config.mode,
        buffer_capacity: config.buffer_capacity,
        sink: config.sink.clone(),
        selected_ranks: config.selected_ranks.clone(),
        hostname: node.config.hostname.clone(),
        consumer_interval: Duration::from_millis(2),
    });
    for p in &config.disabled_patterns {
        session.disable_matching(p);
    }
    let sampler = config
        .sampling
        .clone()
        .map(|s| Sampler::start(node.clone(), s));

    let t0 = Instant::now();
    workload.run(node);
    node.synchronize();
    let wall = t0.elapsed();

    if let Some(s) = sampler {
        s.stop();
    }
    let session = uninstall_session().expect("session vanished");
    let stats = session.stats();
    let trace = match config.sink {
        SinkKind::Null => None,
        _ => Some(btf::collect(
            &session,
            &[("app".to_string(), workload.name().to_string())],
        )),
    };
    RunReport {
        app: workload.name().to_string(),
        config: config.label(),
        wall,
        stats: Some(stats),
        trace,
    }
}

/// Run baseline + each config, with one warmup baseline run first (primes
/// PJRT compile caches so module-create cost doesn't skew a single cell).
/// Returns reports in the same order as `configs`, prefixed by baseline.
pub fn run_matrix(
    node: &Arc<Node>,
    workload: &dyn Workload,
    configs: &[IprofConfig],
) -> Vec<RunReport> {
    // warmup (not reported)
    let _ = run(node, workload, &IprofConfig::baseline());
    let mut reports = vec![run(node, workload, &IprofConfig::baseline())];
    for c in configs {
        reports.push(run(node, workload, c));
    }
    reports
}

/// Percentage overhead of `traced` relative to `base`.
pub fn overhead_pct(base: Duration, traced: Duration) -> f64 {
    if base.as_nanos() == 0 {
        return 0.0;
    }
    (traced.as_secs_f64() - base.as_secs_f64()) / base.as_secs_f64() * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::hecbench;
    use crate::device::NodeConfig;
    use crate::tracer::session::test_support;

    #[test]
    fn baseline_run_has_no_stats() {
        let _g = test_support::lock();
        let node = Node::new(NodeConfig::test_small());
        let apps = hecbench::suite();
        let app = apps.iter().find(|a| a.name() == "saxpy-ze").unwrap();
        let r = run(&node, app.as_ref(), &IprofConfig::baseline());
        assert!(r.stats.is_none());
        assert!(r.trace.is_none());
        assert!(r.wall > Duration::ZERO);
    }

    #[test]
    fn traced_run_produces_trace_and_tally() {
        let _g = test_support::lock();
        let node = Node::new(NodeConfig::test_small());
        let apps = hecbench::suite();
        let app = apps.iter().find(|a| a.name() == "saxpy-ze").unwrap();
        let r = run(&node, app.as_ref(), &IprofConfig::default());
        let stats = r.stats.as_ref().unwrap();
        assert!(stats.written > 50, "saxpy-ze wrote {} events", stats.written);
        let tally = r.tally().unwrap();
        assert!(tally.host.keys().any(|(api, _)| api == "ZE"));
        assert!(!tally.device.is_empty(), "device rows from profiling events");
    }

    #[test]
    fn analyze_drives_multiple_sinks_in_one_pass() {
        let _g = test_support::lock();
        let node = Node::new(NodeConfig::test_small());
        let apps = hecbench::suite();
        let app = apps.iter().find(|a| a.name() == "saxpy-ze").unwrap();
        let r = run(&node, app.as_ref(), &IprofConfig::default());
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![
            Box::new(crate::analysis::TallySink::new()),
            Box::new(crate::analysis::TimelineSink::new()),
        ];
        let reports = r.analyze(&mut sinks).unwrap().unwrap();
        assert_eq!(reports.len(), 2);
        assert!(reports[0].payload().unwrap().contains("Time(%)"));
        assert!(reports[1].payload().unwrap().contains("traceEvents"));
        // baseline has no trace -> None
        let base = run(&node, app.as_ref(), &IprofConfig::baseline());
        assert!(base.analyze(&mut sinks).is_none());
    }

    #[test]
    fn config_labels_match_paper() {
        assert_eq!(IprofConfig::baseline().label(), "base");
        assert_eq!(IprofConfig::paper_config(TracingMode::Default, false).label(), "T-default");
        assert_eq!(IprofConfig::paper_config(TracingMode::Minimal, true).label(), "TS-min");
        assert_eq!(IprofConfig::paper_config(TracingMode::Full, true).label(), "TS-full");
    }

    #[test]
    fn overhead_pct_math() {
        assert!((overhead_pct(Duration::from_secs(1), Duration::from_millis(1050)) - 5.0).abs() < 1e-9);
        assert_eq!(overhead_pct(Duration::ZERO, Duration::from_secs(1)), 0.0);
    }
}
