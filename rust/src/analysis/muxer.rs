//! Muxer: k-way merge of per-thread streams into one time-ordered
//! message sequence (babeltrace2's `muxer` component).

use super::msg::{EventMsg, ParsedTrace};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

struct HeapEntry {
    ts: u64,
    stream: usize,
    index: usize,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        (self.ts, self.stream, self.index) == (other.ts, other.stream, other.index)
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.ts, self.stream, self.index).cmp(&(other.ts, other.stream, other.index))
    }
}

/// Merge all streams by timestamp (stable across streams by stream index).
pub fn mux(trace: &ParsedTrace) -> Vec<EventMsg> {
    let total: usize = trace.streams.iter().map(|s| s.len()).sum();
    let mut out = Vec::with_capacity(total);
    let mut heap: BinaryHeap<Reverse<HeapEntry>> = BinaryHeap::new();
    for (si, s) in trace.streams.iter().enumerate() {
        if !s.is_empty() {
            heap.push(Reverse(HeapEntry { ts: s[0].ts, stream: si, index: 0 }));
        }
    }
    while let Some(Reverse(e)) = heap.pop() {
        let stream = &trace.streams[e.stream];
        out.push(stream[e.index].clone());
        let next = e.index + 1;
        if next < stream.len() {
            heap.push(Reverse(HeapEntry { ts: stream[next].ts, stream: e.stream, index: next }));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::msg::parse_trace;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};

    #[test]
    fn mux_produces_global_time_order_across_threads() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let mut handles = vec![];
        for _ in 0..4 {
            handles.push(std::thread::spawn(move || {
                for _ in 0..200 {
                    emit(class, |e| {
                        e.u64(1);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        assert!(parsed.streams.len() >= 4);
        let merged = mux(&parsed);
        assert_eq!(merged.len(), 800);
        for w in merged.windows(2) {
            assert!(w[0].ts <= w[1].ts, "mux must be time-ordered");
        }
    }

    #[test]
    fn mux_empty_trace_is_empty() {
        let trace = crate::tracer::btf::TraceData {
            metadata: crate::tracer::btf::generate_metadata(&[]),
            streams: vec![],
        };
        let parsed = parse_trace(&trace).unwrap();
        assert!(mux(&parsed).is_empty());
    }
}
