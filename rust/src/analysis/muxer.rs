//! Muxer: k-way merge of per-thread streams into one time-ordered
//! message sequence (babeltrace2's `muxer` component).
//!
//! The merge is exposed as [`MessageSource`], a *lazy* message iterator:
//! it holds one heap entry per stream and yields borrowed `&EventMsg`
//! references in global time order, so a full analysis pass allocates
//! O(#streams) — never an O(total-events) cloned vector. (The seed's
//! eager `mux` shim cloned every event; it is gone — a call site that
//! genuinely needs owned data writes
//! `MessageSource::new(&parsed).cloned().collect()`.)

use super::msg::{EventMsg, ParsedTrace};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

struct HeapEntry {
    ts: u64,
    stream: usize,
    index: usize,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        (self.ts, self.stream, self.index) == (other.ts, other.stream, other.index)
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.ts, self.stream, self.index).cmp(&(other.ts, other.stream, other.index))
    }
}

/// Lazy k-way merge over the streams of a [`ParsedTrace`].
///
/// Yields `&EventMsg` in non-decreasing timestamp order; ties are broken
/// by stream index (stable across streams) and then by in-stream index —
/// the canonical global order every other path (live merge, remote
/// merge) reproduces byte-for-byte.
pub struct MessageSource<'a> {
    streams: &'a [Vec<EventMsg>],
    heap: BinaryHeap<Reverse<HeapEntry>>,
    remaining: usize,
}

impl<'a> MessageSource<'a> {
    /// Open a message source over a parsed trace.
    pub fn new(trace: &'a ParsedTrace) -> Self {
        Self::over_streams(&trace.streams)
    }

    /// Open a message source over raw per-stream message vectors (each
    /// stream must be in non-decreasing timestamp order, as produced by
    /// [`super::msg::parse_trace`]).
    pub fn over_streams(streams: &'a [Vec<EventMsg>]) -> Self {
        let mut heap = BinaryHeap::with_capacity(streams.len());
        for (si, s) in streams.iter().enumerate() {
            if !s.is_empty() {
                heap.push(Reverse(HeapEntry { ts: s[0].ts, stream: si, index: 0 }));
            }
        }
        let remaining = streams.iter().map(|s| s.len()).sum();
        MessageSource { streams, heap, remaining }
    }
}

impl<'a> Iterator for MessageSource<'a> {
    type Item = &'a EventMsg;

    fn next(&mut self) -> Option<&'a EventMsg> {
        let Reverse(e) = self.heap.pop()?;
        let stream = &self.streams[e.stream];
        let next = e.index + 1;
        if next < stream.len() {
            self.heap.push(Reverse(HeapEntry {
                ts: stream[next].ts,
                stream: e.stream,
                index: next,
            }));
        }
        self.remaining -= 1;
        Some(&stream[e.index])
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl<'a> ExactSizeIterator for MessageSource<'a> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::msg::parse_trace;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};

    #[test]
    fn message_source_produces_global_time_order_across_threads() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let mut handles = vec![];
        for _ in 0..4 {
            handles.push(std::thread::spawn(move || {
                for _ in 0..200 {
                    emit(class, |e| {
                        e.u64(1);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        assert!(parsed.streams.len() >= 4);
        let merged: Vec<u64> = MessageSource::new(&parsed).map(|m| m.ts).collect();
        assert_eq!(merged.len(), 800);
        for w in merged.windows(2) {
            assert!(w[0] <= w[1], "merge must be time-ordered");
        }
    }

    #[test]
    fn message_source_is_exact_size_and_stable_across_passes() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let class = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let mut handles = vec![];
        for _ in 0..3 {
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    emit(class, |e| {
                        e.u64(1);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        let owned: Vec<EventMsg> = MessageSource::new(&parsed).cloned().collect();
        let src = MessageSource::new(&parsed);
        assert_eq!(src.len(), owned.len());
        assert_eq!(owned.len(), 150);
        // two lazy passes over the same parsed trace yield the identical
        // sequence — the merge is a pure function of the streams
        for (lazy, first) in MessageSource::new(&parsed).zip(owned.iter()) {
            assert_eq!(lazy.ts, first.ts);
            assert_eq!(lazy.tid, first.tid);
            assert_eq!(lazy.class.id, first.class.id);
        }
    }

    #[test]
    fn empty_trace_yields_empty_merge() {
        let trace = crate::tracer::btf::TraceData {
            metadata: crate::tracer::btf::generate_metadata(&[]),
            streams: vec![],
        };
        let parsed = parse_trace(&trace).unwrap();
        assert_eq!(MessageSource::new(&parsed).count(), 0);
    }
}
