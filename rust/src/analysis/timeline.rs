//! Timeline plugin: Perfetto-compatible chrome-trace JSON (Fig. 5/6).
//!
//! Rows match the paper's layout: per (hostname, process) a host-thread
//! track with the API-call spans, a device track with the GPU command
//! spans (from profiling events), and per GPU the telemetry counter
//! tracks: Power Domain 0/1/2, Frequency Domain 0/1, ComputeEngine (%)
//! Domain 0/1, CopyEngine (%) Domain 0/1. Perfetto opens chrome-trace
//! JSON directly, standing in for the paper's protobuf encoder.
//!
//! [`TimelineSink`] is the streaming form: device/telemetry rows are
//! rendered to JSON the moment each message flows past, and host spans
//! are rendered as the interval filter completes them (only the rendered
//! text plus a start-timestamp key is retained for the final stable
//! sort, never the messages themselves). The eager [`timeline_json`]
//! shim keeps the old two-slice signature.

use super::interval::Interval;
use super::msg::EventMsg;
use super::sink::{AnalysisSink, Report};

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Render one host API span as a chrome-trace complete event.
fn interval_entry(iv: &Interval) -> String {
    format!(
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":{},\"tid\":{}}}",
        esc(&iv.name),
        esc(&iv.api),
        iv.start / 1000,
        iv.duration().max(1) / 1000,
        iv.rank,
        iv.tid
    )
}

/// Render one raw message as a device span or telemetry counter entry,
/// if it is one of the profiling/sampling classes.
fn event_entry(m: &EventMsg) -> Option<String> {
    match m.class.name.as_str() {
        "lttng_ust_profiling:command_completed" => {
            let device = m.field("device").map(|v| v.as_u64()).unwrap_or(0);
            let kind = m.field("kind").map(|v| v.as_str()).unwrap_or("");
            let name = m.field("name").map(|v| v.as_str()).unwrap_or("");
            let label = if kind == "kernel" { name } else { kind };
            let s = m.field("ts_start").map(|v| v.as_u64()).unwrap_or(0);
            let e = m.field("ts_end").map(|v| v.as_u64()).unwrap_or(0);
            let engine = m.field("engine_ordinal").map(|v| v.as_u64()).unwrap_or(0);
            Some(format!(
                "{{\"name\":\"{}\",\"cat\":\"device\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":\"Device {:#x}\",\"tid\":\"engine {}\"}}",
                esc(label),
                s / 1000,
                (e.saturating_sub(s)).max(1) / 1000,
                device,
                engine
            ))
        }
        "lttng_ust_sampling:gpu_power" => {
            let device = m.field("device").map(|v| v.as_u64()).unwrap_or(0);
            let domain = m.field("domain").map(|v| v.as_u64()).unwrap_or(0);
            let watts = m.field("watts").map(|v| v.as_f64()).unwrap_or(0.0);
            Some(format!(
                "{{\"name\":\"GPU Power Domain {domain}\",\"ph\":\"C\",\"ts\":{},\"pid\":\"Device {device:#x}\",\"args\":{{\"W\":{watts:.1}}}}}",
                m.ts / 1000
            ))
        }
        "lttng_ust_sampling:gpu_frequency" => {
            let device = m.field("device").map(|v| v.as_u64()).unwrap_or(0);
            let domain = m.field("domain").map(|v| v.as_u64()).unwrap_or(0);
            let mhz = m.field("mhz").map(|v| v.as_f64()).unwrap_or(0.0);
            Some(format!(
                "{{\"name\":\"GPU Frequency Domain {domain}\",\"ph\":\"C\",\"ts\":{},\"pid\":\"Device {device:#x}\",\"args\":{{\"MHz\":{mhz:.0}}}}}",
                m.ts / 1000
            ))
        }
        "lttng_ust_sampling:gpu_engine_util" => {
            let device = m.field("device").map(|v| v.as_u64()).unwrap_or(0);
            let kind = m.field("engine_kind").map(|v| v.as_u64()).unwrap_or(0);
            let domain = m.field("domain").map(|v| v.as_u64()).unwrap_or(0);
            let util = m.field("util").map(|v| v.as_f64()).unwrap_or(0.0);
            let engine = if kind == 0 { "ComputeEngine" } else { "CopyEngine" };
            Some(format!(
                "{{\"name\":\"{engine} (%) Domain {domain}\",\"ph\":\"C\",\"ts\":{},\"pid\":\"Device {device:#x}\",\"args\":{{\"pct\":{:.1}}}}}",
                m.ts / 1000,
                util * 100.0
            ))
        }
        _ => None,
    }
}

/// Assemble the final document: host entries (already sorted by start),
/// then device/telemetry entries, comma-joined.
fn assemble(host: Vec<String>, device: Vec<String>) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    for entry in host.into_iter().chain(device) {
        if !std::mem::take(&mut first) {
            out.push_str(",\n");
        }
        out.push_str(&entry);
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}");
    out
}

/// Build chrome-trace JSON from paired intervals and raw messages
/// (profiling + sampling events are picked out of `msgs`). Eager entry
/// point over the shared renderers; `intervals` must already be sorted
/// by start (as [`super::interval::intervals_of`] returns them).
pub fn timeline_json(intervals: &[Interval], msgs: &[EventMsg]) -> String {
    let host: Vec<String> = intervals.iter().map(interval_entry).collect();
    let device: Vec<String> = msgs.iter().filter_map(event_entry).collect();
    assemble(host, device)
}

/// The Timeline plugin as a streaming [`AnalysisSink`].
///
/// Memory stays proportional to the *output* (rendered JSON entries),
/// not to the trace: no `EventMsg` or `Interval` is retained. Host spans
/// carry their start timestamp so the finish stage can stable-sort them
/// into the same start order the eager path produces.
#[derive(Default)]
pub struct TimelineSink {
    host: Vec<(u64, String)>,
    device: Vec<String>,
}

impl TimelineSink {
    /// Empty sink.
    pub fn new() -> Self {
        Self::default()
    }
}

impl AnalysisSink for TimelineSink {
    fn name(&self) -> &'static str {
        "timeline"
    }

    fn consume_event(&mut self, m: &EventMsg) {
        if let Some(entry) = event_entry(m) {
            self.device.push(entry);
        }
    }

    fn consume_interval(&mut self, iv: &Interval) {
        self.host.push((iv.start, interval_entry(iv)));
    }

    fn finish(&mut self) -> Report {
        let mut host = std::mem::take(&mut self.host);
        // stable: same-start spans keep completion order, matching the
        // eager intervals_of sort
        host.sort_by_key(|(start, _)| *start);
        let host: Vec<String> = host.into_iter().map(|(_, e)| e).collect();
        Report::Json(assemble(host, std::mem::take(&mut self.device)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::interval::intervals_of;
    use crate::analysis::msg::parse_trace;
    use crate::analysis::muxer::MessageSource;
    use crate::analysis::sink::run_pipeline;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};

    fn sample_parsed() -> crate::analysis::ParsedTrace {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let e = class_by_name("lttng_ust_ze:zeCommandQueueSynchronize_entry").unwrap();
        let x = class_by_name("lttng_ust_ze:zeCommandQueueSynchronize_exit").unwrap();
        emit(e, |en| {
            en.ptr(0x51).u64(u64::MAX);
        });
        emit(x, |en| {
            en.u64(0);
        });
        let prof = class_by_name("lttng_ust_profiling:command_completed").unwrap();
        emit(prof, |en| {
            en.ptr(0x1000)
                .u32(0)
                .u32(0)
                .str("kernel")
                .str("conv1d")
                .ptr(0x51)
                .u64(1000)
                .u64(9000)
                .u64(0);
        });
        let pw = class_by_name("lttng_ust_sampling:gpu_power").unwrap();
        emit(pw, |en| {
            en.ptr(0x1000).u32(0).f64(421.5).u64(123456);
        });
        let fu = class_by_name("lttng_ust_sampling:gpu_engine_util").unwrap();
        emit(fu, |en| {
            en.ptr(0x1000).u32(0).u32(1).f64(0.73);
        });
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        parse_trace(&trace).unwrap()
    }

    fn build_sample() -> String {
        let parsed = sample_parsed();
        let msgs: Vec<_> = MessageSource::new(&parsed).cloned().collect();
        timeline_json(&intervals_of(&parsed), &msgs)
    }

    #[test]
    fn json_has_host_device_and_counter_rows() {
        let j = build_sample();
        assert!(j.contains("\"name\":\"zeCommandQueueSynchronize\""));
        assert!(j.contains("\"name\":\"conv1d\""));
        assert!(j.contains("GPU Power Domain 0"));
        assert!(j.contains("ComputeEngine (%) Domain 1"));
        assert!(j.contains("\"traceEvents\""));
    }

    #[test]
    fn json_is_structurally_balanced() {
        let j = build_sample();
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        assert!(j.ends_with("\"displayTimeUnit\":\"ms\"}"));
    }

    #[test]
    fn streaming_sink_is_byte_identical_to_eager_path() {
        let parsed = sample_parsed();
        let msgs: Vec<_> = MessageSource::new(&parsed).cloned().collect();
        let eager = timeline_json(&intervals_of(&parsed), &msgs);
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TimelineSink::new())];
        let reports = run_pipeline(&parsed, &mut sinks);
        assert_eq!(reports[0].payload().unwrap(), eager);
    }
}
