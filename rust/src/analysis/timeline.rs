//! Timeline plugin: Perfetto-compatible chrome-trace JSON (Fig. 5/6).
//!
//! Rows match the paper's layout: per (hostname, process) a host-thread
//! track with the API-call spans, a device track with the GPU command
//! spans (from profiling events), and per GPU the telemetry counter
//! tracks: Power Domain 0/1/2, Frequency Domain 0/1, ComputeEngine (%)
//! Domain 0/1, CopyEngine (%) Domain 0/1. Perfetto opens chrome-trace
//! JSON directly, standing in for the paper's protobuf encoder.

use super::interval::Interval;
use super::msg::EventMsg;
use std::fmt::Write as _;

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Build chrome-trace JSON from paired intervals and raw messages
/// (profiling + sampling events are picked out of `msgs`).
pub fn timeline_json(intervals: &[Interval], msgs: &[EventMsg]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |s: String, out: &mut String| {
        if !std::mem::take(&mut first) {
            out.push_str(",\n");
        }
        out.push_str(&s);
    };

    // Host API spans: pid = rank, tid = thread.
    for iv in intervals {
        push(
            format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":{},\"tid\":{}}}",
                esc(&iv.name),
                esc(&iv.api),
                iv.start / 1000,
                iv.duration().max(1) / 1000,
                iv.rank,
                iv.tid
            ),
            &mut out,
        );
    }

    // Device command spans + telemetry counters.
    for m in msgs {
        match m.class.name.as_str() {
            "lttng_ust_profiling:command_completed" => {
                let device = m.field("device").map(|v| v.as_u64()).unwrap_or(0);
                let kind = m.field("kind").map(|v| v.as_str()).unwrap_or("");
                let name = m.field("name").map(|v| v.as_str()).unwrap_or("");
                let label = if kind == "kernel" { name } else { kind };
                let s = m.field("ts_start").map(|v| v.as_u64()).unwrap_or(0);
                let e = m.field("ts_end").map(|v| v.as_u64()).unwrap_or(0);
                let engine = m.field("engine_ordinal").map(|v| v.as_u64()).unwrap_or(0);
                push(
                    format!(
                        "{{\"name\":\"{}\",\"cat\":\"device\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":\"Device {:#x}\",\"tid\":\"engine {}\"}}",
                        esc(label),
                        s / 1000,
                        (e.saturating_sub(s)).max(1) / 1000,
                        device,
                        engine
                    ),
                    &mut out,
                );
            }
            "lttng_ust_sampling:gpu_power" => {
                let device = m.field("device").map(|v| v.as_u64()).unwrap_or(0);
                let domain = m.field("domain").map(|v| v.as_u64()).unwrap_or(0);
                let watts = m.field("watts").map(|v| v.as_f64()).unwrap_or(0.0);
                push(
                    format!(
                        "{{\"name\":\"GPU Power Domain {domain}\",\"ph\":\"C\",\"ts\":{},\"pid\":\"Device {device:#x}\",\"args\":{{\"W\":{watts:.1}}}}}",
                        m.ts / 1000
                    ),
                    &mut out,
                );
            }
            "lttng_ust_sampling:gpu_frequency" => {
                let device = m.field("device").map(|v| v.as_u64()).unwrap_or(0);
                let domain = m.field("domain").map(|v| v.as_u64()).unwrap_or(0);
                let mhz = m.field("mhz").map(|v| v.as_f64()).unwrap_or(0.0);
                push(
                    format!(
                        "{{\"name\":\"GPU Frequency Domain {domain}\",\"ph\":\"C\",\"ts\":{},\"pid\":\"Device {device:#x}\",\"args\":{{\"MHz\":{mhz:.0}}}}}",
                        m.ts / 1000
                    ),
                    &mut out,
                );
            }
            "lttng_ust_sampling:gpu_engine_util" => {
                let device = m.field("device").map(|v| v.as_u64()).unwrap_or(0);
                let kind = m.field("engine_kind").map(|v| v.as_u64()).unwrap_or(0);
                let domain = m.field("domain").map(|v| v.as_u64()).unwrap_or(0);
                let util = m.field("util").map(|v| v.as_f64()).unwrap_or(0.0);
                let engine = if kind == 0 { "ComputeEngine" } else { "CopyEngine" };
                push(
                    format!(
                        "{{\"name\":\"{engine} (%) Domain {domain}\",\"ph\":\"C\",\"ts\":{},\"pid\":\"Device {device:#x}\",\"args\":{{\"pct\":{:.1}}}}}",
                        m.ts / 1000,
                        util * 100.0
                    ),
                    &mut out,
                );
            }
            _ => {}
        }
    }

    let mut meta = String::new();
    let _ = write!(meta, "\n],\"displayTimeUnit\":\"ms\"}}");
    out.push_str(&meta);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::msg::parse_trace;
    use crate::analysis::muxer::mux;
    use crate::analysis::pair_intervals;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};

    fn build_sample() -> String {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let e = class_by_name("lttng_ust_ze:zeCommandQueueSynchronize_entry").unwrap();
        let x = class_by_name("lttng_ust_ze:zeCommandQueueSynchronize_exit").unwrap();
        emit(e, |en| {
            en.ptr(0x51).u64(u64::MAX);
        });
        emit(x, |en| {
            en.u64(0);
        });
        let prof = class_by_name("lttng_ust_profiling:command_completed").unwrap();
        emit(prof, |en| {
            en.ptr(0x1000)
                .u32(0)
                .u32(0)
                .str("kernel")
                .str("conv1d")
                .ptr(0x51)
                .u64(1000)
                .u64(9000)
                .u64(0);
        });
        let pw = class_by_name("lttng_ust_sampling:gpu_power").unwrap();
        emit(pw, |en| {
            en.ptr(0x1000).u32(0).f64(421.5).u64(123456);
        });
        let fu = class_by_name("lttng_ust_sampling:gpu_engine_util").unwrap();
        emit(fu, |en| {
            en.ptr(0x1000).u32(0).u32(1).f64(0.73);
        });
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let msgs = mux(&parse_trace(&trace).unwrap());
        let iv = pair_intervals(&msgs);
        timeline_json(&iv, &msgs)
    }

    #[test]
    fn json_has_host_device_and_counter_rows() {
        let j = build_sample();
        assert!(j.contains("\"name\":\"zeCommandQueueSynchronize\""));
        assert!(j.contains("\"name\":\"conv1d\""));
        assert!(j.contains("GPU Power Domain 0"));
        assert!(j.contains("ComputeEngine (%) Domain 1"));
        assert!(j.contains("\"traceEvents\""));
    }

    #[test]
    fn json_is_structurally_balanced() {
        let j = build_sample();
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        assert!(j.ends_with("\"displayTimeUnit\":\"ms\"}"));
    }
}
