//! Message model: decoded trace events with stream context.

use crate::tracer::btf::{iter_records, parse_metadata, DecodedClass, Metadata, TraceData};
use crate::tracer::encoder::{decode_payload, FieldValue};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// One decoded event message.
#[derive(Debug, Clone)]
pub struct EventMsg {
    /// Timestamp (trace-clock ns).
    pub ts: u64,
    /// Producing rank.
    pub rank: u32,
    /// Producing thread.
    pub tid: u32,
    /// Hostname.
    pub hostname: Arc<str>,
    /// Event class descriptor.
    pub class: Arc<DecodedClass>,
    /// Decoded field values (descriptor order).
    pub fields: Vec<FieldValue>,
}

impl EventMsg {
    /// Field value by name.
    pub fn field(&self, name: &str) -> Option<&FieldValue> {
        self.class
            .fields
            .iter()
            .position(|f| f.name == name)
            .map(|i| &self.fields[i])
    }
}

/// A fully parsed trace: metadata + per-stream decoded events (stream
/// order preserved; iterate [`crate::analysis::MessageSource`] for lazy
/// time order).
#[derive(Debug)]
pub struct ParsedTrace {
    /// Parsed metadata.
    pub metadata: Metadata,
    /// Per-stream events, each stream in emit order.
    pub streams: Vec<Vec<EventMsg>>,
}

impl ParsedTrace {
    /// Total decoded event count across streams.
    pub fn event_count(&self) -> usize {
        self.streams.iter().map(|s| s.len()).sum()
    }
}

/// Decode a [`TraceData`] into messages.
pub fn parse_trace(trace: &TraceData) -> Result<ParsedTrace> {
    let metadata = parse_metadata(&trace.metadata)?;
    let classes: HashMap<u32, Arc<DecodedClass>> =
        metadata.classes.iter().map(|(id, c)| (*id, Arc::new(c.clone()))).collect();
    let mut streams = Vec::with_capacity(trace.streams.len());
    for s in &trace.streams {
        let hostname: Arc<str> = Arc::from(s.hostname.as_str());
        let mut events = Vec::new();
        iter_records(&s.bytes, |id, ts, payload| {
            if let Some(class) = classes.get(&id) {
                events.push(EventMsg {
                    ts,
                    rank: s.rank,
                    tid: s.tid,
                    hostname: hostname.clone(),
                    class: class.clone(),
                    fields: decode_payload(&class.fields, payload),
                });
            }
        });
        streams.push(events);
    }
    Ok(ParsedTrace { metadata, streams })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};

    #[test]
    fn parse_trace_decodes_fields_by_name() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let class = class_by_name("lttng_ust_ze:zeCommandListAppendMemoryCopy_entry").unwrap();
        emit(class, |e| {
            e.ptr(0x1150_0000).ptr(0xff00_1234).ptr(0x7f00_5678).u64(4096).ptr(0).u64(0).ptr(0);
        });
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        let all: Vec<_> = parsed.streams.iter().flatten().collect();
        assert_eq!(all.len(), 1);
        let m = all[0];
        assert_eq!(m.field("size").unwrap().as_u64(), 4096);
        assert_eq!(m.field("dstptr").unwrap().as_u64(), 0xff00_1234);
        assert!(m.field("nope").is_none());
        assert_eq!(m.class.api_function(), "zeCommandListAppendMemoryCopy");
    }
}
