//! Interval pairing: `_entry`/`_exit` events -> host call spans.
//!
//! The "Interval plugins" of the paper (Fig. 1a): timing analysis based on
//! the start and end times of events. Pairing is per (rank, tid) with a
//! stack, so nested calls (HIP wrappers around ZE calls) pair correctly.
//!
//! [`IntervalTracker`] is the streaming form: it consumes one message at
//! a time and emits each [`Interval`] the moment its exit arrives, so a
//! single pass over a [`super::muxer::MessageSource`] produces spans with
//! O(open-call-depth) state instead of an O(total-events) buffer.
//! [`intervals_of`] materializes that pass for callers that want the
//! span vector. (The seed's eager `pair_intervals` shim is gone.)

use super::msg::{EventMsg, ParsedTrace};
use super::muxer::MessageSource;
use std::collections::HashMap;
use std::sync::Arc;

/// One paired host API call.
#[derive(Debug, Clone)]
pub struct Interval {
    /// API function name (`zeInit`, `hipMemcpy`, ...).
    pub name: String,
    /// Backend label (ZE, CUDA, HIP, ...).
    pub api: String,
    /// Rank.
    pub rank: u32,
    /// Thread.
    pub tid: u32,
    /// Hostname.
    pub hostname: Arc<str>,
    /// Entry timestamp (ns).
    pub start: u64,
    /// Exit timestamp (ns).
    pub end: u64,
    /// Nesting depth at entry (0 = top level).
    pub depth: u32,
    /// The entry message (full arguments).
    pub entry: EventMsg,
    /// The exit message (result + out values), if the call returned.
    pub exit: Option<EventMsg>,
}

impl Interval {
    /// Span duration in ns.
    pub fn duration(&self) -> u64 {
        self.end.saturating_sub(self.start)
    }
}

struct Open {
    entry: EventMsg,
    depth: u32,
}

/// Incremental entry/exit pairing over a time-ordered message stream.
///
/// Feed every muxed message to [`IntervalTracker::push`]; completed spans
/// are handed to the `emit` callback as soon as their exit arrives (the
/// filter stage of the source → muxer → filter → sink graph). Call
/// [`IntervalTracker::finish`] at end of stream to close dangling entries
/// (no exit before end of trace) with `exit: None` and `end` = last seen
/// timestamp.
#[derive(Default)]
pub struct IntervalTracker {
    stacks: HashMap<(u32, u32), Vec<Open>>,
    last_ts: u64,
}

impl IntervalTracker {
    /// Empty tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Consume one time-ordered message; emit any spans it completes.
    pub fn push(&mut self, m: &EventMsg, mut emit: impl FnMut(Interval)) {
        self.last_ts = self.last_ts.max(m.ts);
        if !(m.class.is_entry() || m.class.is_exit()) {
            return;
        }
        let key = (m.rank, m.tid);
        let stack = self.stacks.entry(key).or_default();
        if m.class.is_entry() {
            let depth = stack.len() as u32;
            stack.push(Open { entry: m.clone(), depth });
        } else {
            // find the matching open entry from the top (tolerates missing
            // exits in the middle due to ring-buffer drops)
            let fname = m.class.api_function();
            if let Some(pos) = stack.iter().rposition(|o| o.entry.class.api_function() == fname) {
                let drained: Vec<Open> = stack.drain(pos..).collect();
                let mut iter = drained.into_iter();
                let open = iter.next().unwrap();
                // anything above the match lost its exit: close as unbalanced
                for lost in iter {
                    emit(Interval {
                        name: lost.entry.class.api_function().to_string(),
                        api: lost.entry.class.api.clone(),
                        rank: lost.entry.rank,
                        tid: lost.entry.tid,
                        hostname: lost.entry.hostname.clone(),
                        start: lost.entry.ts,
                        end: m.ts,
                        depth: lost.depth,
                        entry: lost.entry,
                        exit: None,
                    });
                }
                emit(Interval {
                    name: fname.to_string(),
                    api: open.entry.class.api.clone(),
                    rank: open.entry.rank,
                    tid: open.entry.tid,
                    hostname: open.entry.hostname.clone(),
                    start: open.entry.ts,
                    end: m.ts,
                    depth: open.depth,
                    entry: open.entry,
                    exit: Some(m.clone()),
                });
            }
            // exit without any entry: dropped entry record — ignore
        }
    }

    /// Number of still-open (unmatched) entries.
    pub fn open_count(&self) -> usize {
        self.stacks.values().map(|s| s.len()).sum()
    }

    /// End of stream: close dangling entries at the last seen timestamp,
    /// in (rank, tid) order so the flush is deterministic across runs.
    pub fn finish(&mut self, mut emit: impl FnMut(Interval)) {
        let last_ts = self.last_ts;
        let mut stacks: Vec<_> = std::mem::take(&mut self.stacks).into_iter().collect();
        stacks.sort_by_key(|(k, _)| *k);
        for (_, stack) in stacks {
            for open in stack {
                emit(Interval {
                    name: open.entry.class.api_function().to_string(),
                    api: open.entry.class.api.clone(),
                    rank: open.entry.rank,
                    tid: open.entry.tid,
                    hostname: open.entry.hostname.clone(),
                    start: open.entry.ts,
                    end: last_ts,
                    depth: open.depth,
                    entry: open.entry,
                    exit: None,
                });
            }
        }
    }
}

/// Run any time-ordered borrowed message sequence through a fresh
/// [`IntervalTracker`] and return the spans sorted by start timestamp
/// (stable, so same-start spans keep completion order).
fn collect_spans<'m>(msgs: impl IntoIterator<Item = &'m EventMsg>) -> Vec<Interval> {
    let mut tracker = IntervalTracker::new();
    let mut out = Vec::new();
    for m in msgs {
        tracker.push(m, |iv| out.push(iv));
    }
    tracker.finish(|iv| out.push(iv));
    out.sort_by_key(|i| i.start);
    out
}

/// Single-pass span extraction straight from a parsed trace: lazy muxing
/// through [`MessageSource`] into an [`IntervalTracker`], no intermediate
/// `Vec<EventMsg>`. Spans are sorted by start timestamp (stable, so
/// same-start spans keep completion order); unbalanced entries (no exit
/// before end of trace) come out with `exit: None` and `end` = last seen
/// timestamp.
pub fn intervals_of(parsed: &ParsedTrace) -> Vec<Interval> {
    collect_spans(MessageSource::new(parsed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::msg::parse_trace;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};

    fn record<F: FnOnce()>(f: F) -> ParsedTrace {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        f();
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        parse_trace(&trace).unwrap()
    }

    #[test]
    fn simple_pairing() {
        let parsed = record(|| {
            let e = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
            let x = class_by_name("lttng_ust_ze:zeInit_exit").unwrap();
            emit(e, |en| {
                en.u64(0);
            });
            emit(x, |en| {
                en.u64(0);
            });
        });
        let iv = intervals_of(&parsed);
        assert_eq!(iv.len(), 1);
        assert_eq!(iv[0].name, "zeInit");
        assert_eq!(iv[0].depth, 0);
        assert!(iv[0].exit.is_some());
        assert!(iv[0].end >= iv[0].start);
    }

    #[test]
    fn nested_layering_depths() {
        let parsed = record(|| {
            // hipMemcpy wrapping a ze append (the HIPLZ pattern)
            let he = class_by_name("lttng_ust_hip:hipMemcpy_entry").unwrap();
            let hx = class_by_name("lttng_ust_hip:hipMemcpy_exit").unwrap();
            let ze = class_by_name("lttng_ust_ze:zeCommandListClose_entry").unwrap();
            let zx = class_by_name("lttng_ust_ze:zeCommandListClose_exit").unwrap();
            emit(he, |e| {
                e.ptr(1).ptr(2).u64(64).u64(1);
            });
            emit(ze, |e| {
                e.ptr(3);
            });
            emit(zx, |e| {
                e.u64(0);
            });
            emit(hx, |e| {
                e.u64(0);
            });
        });
        let iv = intervals_of(&parsed);
        assert_eq!(iv.len(), 2);
        let hip = iv.iter().find(|i| i.name == "hipMemcpy").unwrap();
        let ze = iv.iter().find(|i| i.name == "zeCommandListClose").unwrap();
        assert_eq!(hip.depth, 0);
        assert_eq!(ze.depth, 1);
        assert!(hip.start <= ze.start && ze.end <= hip.end, "nesting must hold");
    }

    #[test]
    fn dangling_entry_closes_at_trace_end() {
        let parsed = record(|| {
            let e = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
            emit(e, |en| {
                en.u64(0);
            });
        });
        let iv = intervals_of(&parsed);
        assert_eq!(iv.len(), 1);
        assert!(iv[0].exit.is_none());
    }

    #[test]
    fn interleaved_threads_pair_independently() {
        let parsed = record(|| {
            let e = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
            let x = class_by_name("lttng_ust_ze:zeInit_exit").unwrap();
            let t1 = std::thread::spawn(move || {
                for _ in 0..100 {
                    emit(e, |en| {
                        en.u64(0);
                    });
                    emit(x, |en| {
                        en.u64(0);
                    });
                }
            });
            let t2 = std::thread::spawn(move || {
                for _ in 0..100 {
                    emit(e, |en| {
                        en.u64(0);
                    });
                    emit(x, |en| {
                        en.u64(0);
                    });
                }
            });
            t1.join().unwrap();
            t2.join().unwrap();
        });
        let iv = intervals_of(&parsed);
        assert_eq!(iv.len(), 200);
        assert!(iv.iter().all(|i| i.exit.is_some()));
        assert!(iv.iter().all(|i| i.depth == 0));
    }

    #[test]
    fn tracker_emits_completed_spans_immediately() {
        let parsed = record(|| {
            let e = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
            let x = class_by_name("lttng_ust_ze:zeInit_exit").unwrap();
            emit(e, |en| {
                en.u64(0);
            });
            emit(x, |en| {
                en.u64(0);
            });
            emit(e, |en| {
                en.u64(0);
            });
        });
        let mut tracker = IntervalTracker::new();
        let mut emitted = Vec::new();
        for m in MessageSource::new(&parsed) {
            tracker.push(m, |iv| emitted.push(iv));
        }
        // the paired call is out before finish(); the dangling one is not
        assert_eq!(emitted.len(), 1);
        assert_eq!(tracker.open_count(), 1);
        tracker.finish(|iv| emitted.push(iv));
        assert_eq!(emitted.len(), 2);
        assert_eq!(tracker.open_count(), 0);
        assert!(emitted[1].exit.is_none());
    }
}
