//! The sink stage of the analysis graph, and the single-pass driver.
//!
//! THAPI's babeltrace2 graph is source → muxer → filter → sink; this
//! module is the sink contract plus the wiring. Any number of
//! [`AnalysisSink`]s (Tally, Pretty, Timeline, Validate, or user-written
//! plugins) attach to one [`run_pipeline`] call and are fed from a single
//! lazy pass over the trace:
//!
//! * every muxed message is delivered to [`AnalysisSink::consume_event`]
//!   as a borrowed `&EventMsg` (zero-copy — the message lives in the
//!   parsed streams, never in an intermediate vector);
//! * the built-in [`IntervalTracker`] filter pairs `_entry`/`_exit`
//!   messages as they flow and delivers each completed span to
//!   [`AnalysisSink::consume_interval`];
//! * at end of stream, dangling spans are flushed and every sink's
//!   [`AnalysisSink::finish`] produces its [`Report`].
//!
//! Running `iprof -a tally,timeline,validate` therefore decodes and
//! merges the trace exactly once, regardless of how many sinks attach.

use super::interval::{Interval, IntervalTracker};
use super::msg::{EventMsg, ParsedTrace};
use super::muxer::MessageSource;

/// What a sink produces at end of stream.
#[derive(Debug, Clone)]
pub enum Report {
    /// Nothing to show (pure side-effect or state-only sinks).
    None,
    /// Rendered text for stdout (tally table, pretty print, validation).
    Text(String),
    /// A JSON artifact the caller should persist (timeline trace).
    Json(String),
}

impl Report {
    /// The text/JSON payload, if any.
    pub fn payload(&self) -> Option<&str> {
        match self {
            Report::None => None,
            Report::Text(s) | Report::Json(s) => Some(s),
        }
    }
}

/// One analysis plugin attached to the streaming graph.
///
/// Both `consume_*` hooks default to no-ops so a sink only implements the
/// stages it cares about (Pretty consumes events only; Tally consumes
/// both: intervals for host rows, events for device/profiling rows).
pub trait AnalysisSink {
    /// Stable plugin name (`"tally"`, `"timeline"`, ...).
    fn name(&self) -> &'static str;

    /// One time-ordered message (borrowed from the parsed streams).
    fn consume_event(&mut self, _m: &EventMsg) {}

    /// One completed host API span (emitted as soon as its exit arrives;
    /// dangling spans arrive during the end-of-stream flush).
    fn consume_interval(&mut self, _iv: &Interval) {}

    /// Mid-stream snapshot for live mode's periodic refresh
    /// (`iprof --live --refresh <ms>`). A sink opts in by returning an
    /// interim [`Report`] built from its current state; the default
    /// `None` means "not refreshable" and live mode skips it. Must not
    /// disturb the state `finish` will render.
    fn refresh(&mut self) -> Option<Report> {
        None
    }

    /// End of stream: render the result.
    fn finish(&mut self) -> Report;
}

/// The shared pipeline core: interval filter + sink fan-out, one message
/// at a time.
///
/// [`run_pipeline`] drives it from a lazy post-mortem [`MessageSource`];
/// [`crate::live::run_live_pipeline`] drives it from a blocking
/// [`crate::live::LiveSource`] while the application is still running.
/// Both deliver every message to [`AnalysisSink::consume_event`], pair
/// entries/exits through one [`IntervalTracker`], and fan completed
/// spans out to [`AnalysisSink::consume_interval`].
#[derive(Default)]
pub struct PipelineDriver {
    tracker: IntervalTracker,
}

impl PipelineDriver {
    /// Fresh driver (empty interval filter).
    pub fn new() -> Self {
        Self::default()
    }

    /// Deliver one time-ordered message to every sink (and any host span
    /// it completes).
    pub fn feed<S>(&mut self, m: &EventMsg, sinks: &mut [Box<S>])
    where
        S: AnalysisSink + ?Sized,
    {
        for s in sinks.iter_mut() {
            s.consume_event(m);
        }
        self.tracker.push(m, |iv| {
            for s in sinks.iter_mut() {
                s.consume_interval(&iv);
            }
        });
    }

    /// End of stream: flush dangling spans and render every sink's
    /// [`Report`], in sink order.
    pub fn finish<S>(&mut self, sinks: &mut [Box<S>]) -> Vec<Report>
    where
        S: AnalysisSink + ?Sized,
    {
        self.tracker.finish(|iv| {
            for s in sinks.iter_mut() {
                s.consume_interval(&iv);
            }
        });
        sinks.iter_mut().map(|s| s.finish()).collect()
    }
}

/// Drive every sink from one lazy pass over `parsed`.
///
/// Returns one [`Report`] per sink, in sink order. The pass allocates no
/// per-event copies: messages are borrowed from the parsed streams and
/// spans are built incrementally by the interval filter.
pub fn run_pipeline<S>(parsed: &ParsedTrace, sinks: &mut [Box<S>]) -> Vec<Report>
where
    S: AnalysisSink + ?Sized,
{
    let mut driver = PipelineDriver::new();
    for m in MessageSource::new(parsed) {
        driver.feed(m, sinks);
    }
    driver.finish(sinks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::msg::parse_trace;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};

    struct CountingSink {
        events: usize,
        intervals: usize,
    }

    impl AnalysisSink for CountingSink {
        fn name(&self) -> &'static str {
            "count"
        }
        fn consume_event(&mut self, _m: &EventMsg) {
            self.events += 1;
        }
        fn consume_interval(&mut self, _iv: &Interval) {
            self.intervals += 1;
        }
        fn finish(&mut self) -> Report {
            Report::Text(format!("{} events, {} intervals", self.events, self.intervals))
        }
    }

    #[test]
    fn pipeline_fans_one_pass_out_to_all_sinks() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let e = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let x = class_by_name("lttng_ust_ze:zeInit_exit").unwrap();
        for _ in 0..5 {
            emit(e, |en| {
                en.u64(0);
            });
            emit(x, |en| {
                en.u64(0);
            });
        }
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![
            Box::new(CountingSink { events: 0, intervals: 0 }),
            Box::new(CountingSink { events: 0, intervals: 0 }),
        ];
        let reports = run_pipeline(&parsed, &mut sinks);
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert_eq!(r.payload().unwrap(), "10 events, 5 intervals");
        }
    }
}
