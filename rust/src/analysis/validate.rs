//! Post-mortem validation plugin (paper §4.2).
//!
//! Scans a muxed trace for the low-level API mistakes the paper
//! mitigates:
//!
//! * **Uninitialized `pNext`** — `zeDeviceGetProperties` called with a
//!   non-null `pNext` field (undefined behaviour in Level-Zero).
//! * **Unreleased events** — `zeEventCreate`/`cuEventCreate` without a
//!   matching destroy.
//! * **Non-reset command lists** — a command list executed again without
//!   `zeCommandListReset` in between.
//! * **Unreleased modules/kernels** and zero-byte copies as hygiene
//!   warnings.
//!
//! The rules live in the incremental [`Validator`] (observe one message
//! at a time, O(live-handles) state), which backs both the streaming
//! [`ValidateSink`] and the eager [`validate`] shim.

use super::msg::EventMsg;
use super::sink::{AnalysisSink, Report};
use std::collections::{HashMap, HashSet};

/// Finding severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Hygiene issue.
    Warning,
    /// Undefined behaviour / correctness risk.
    Error,
}

/// One validation finding.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Severity.
    pub severity: Severity,
    /// Rule identifier.
    pub rule: &'static str,
    /// Human-readable description.
    pub message: String,
    /// Timestamp of the triggering event (0 for end-of-trace findings).
    pub ts: u64,
}

/// Incremental rule engine: feed it every muxed message via
/// [`Validator::observe`], then [`Validator::finish`] to flush the
/// end-of-trace rules (unreleased handles) and collect sorted findings.
#[derive(Default)]
pub struct Validator {
    findings: Vec<Finding>,
    live_events: HashMap<u64, u64>, // handle -> create ts
    live_modules: HashMap<u64, u64>,
    live_kernels: HashMap<u64, u64>,
    // list handle -> executed-since-reset count
    list_exec: HashMap<u64, u32>,
    flagged_lists: HashSet<u64>,
}

impl Validator {
    /// Empty rule engine.
    pub fn new() -> Self {
        Self::default()
    }

    /// Apply every rule to one time-ordered message.
    pub fn observe(&mut self, m: &EventMsg) {
        match m.class.name.as_str() {
            "lttng_ust_ze:zeDeviceGetProperties_entry" => {
                if let Some(v) = m.field("pDeviceProperties_pNext") {
                    if v.as_u64() != 0 {
                        self.findings.push(Finding {
                            severity: Severity::Error,
                            rule: "ze-uninitialized-pnext",
                            message: format!(
                                "zeDeviceGetProperties called with non-null pNext ({:#x}): \
                                 undefined behaviour — initialize the struct with {{0}} or set \
                                 pNext = NULL",
                                v.as_u64()
                            ),
                            ts: m.ts,
                        });
                    }
                }
            }
            "lttng_ust_ze:zeEventCreate_exit" | "lttng_ust_cuda:cuEventCreate_exit" => {
                if let Some(h) = m.field("*phEvent") {
                    if h.as_u64() != 0 {
                        self.live_events.insert(h.as_u64(), m.ts);
                    }
                }
            }
            "lttng_ust_ze:zeEventDestroy_entry" | "lttng_ust_cuda:cuEventDestroy_entry" => {
                if let Some(h) = m.field("hEvent") {
                    self.live_events.remove(&h.as_u64());
                }
            }
            "lttng_ust_ze:zeModuleCreate_exit" => {
                if let Some(h) = m.field("*phModule") {
                    if h.as_u64() != 0 {
                        self.live_modules.insert(h.as_u64(), m.ts);
                    }
                }
            }
            "lttng_ust_ze:zeModuleDestroy_entry" => {
                if let Some(h) = m.field("hModule") {
                    self.live_modules.remove(&h.as_u64());
                }
            }
            "lttng_ust_ze:zeKernelCreate_exit" => {
                if let Some(h) = m.field("*phKernel") {
                    if h.as_u64() != 0 {
                        self.live_kernels.insert(h.as_u64(), m.ts);
                    }
                }
            }
            "lttng_ust_ze:zeKernelDestroy_entry" => {
                if let Some(h) = m.field("hKernel") {
                    self.live_kernels.remove(&h.as_u64());
                }
            }
            "lttng_ust_ze:zeCommandListReset_entry" => {
                if let Some(h) = m.field("hCommandList") {
                    self.list_exec.insert(h.as_u64(), 0);
                }
            }
            "lttng_ust_ze:zeCommandQueueExecuteCommandLists_entry" => {
                // we cannot see the list array contents (traced as a
                // pointer); execution counting is done via the per-list
                // close/execute pattern below using the queue field only.
            }
            "lttng_ust_ze:zeCommandListClose_entry" => {
                if let Some(h) = m.field("hCommandList") {
                    let c = self.list_exec.entry(h.as_u64()).or_insert(0);
                    // closing again without reset after an execute -> the
                    // §4.2 non-reset pattern
                    if *c > 0 && self.flagged_lists.insert(h.as_u64()) {
                        self.findings.push(Finding {
                            severity: Severity::Error,
                            rule: "ze-list-not-reset",
                            message: format!(
                                "command list {:#x} closed/re-executed without \
                                 zeCommandListReset",
                                h.as_u64()
                            ),
                            ts: m.ts,
                        });
                    }
                    *c += 1;
                }
            }
            "lttng_ust_ze:zeCommandListAppendMemoryCopy_entry" => {
                if let Some(size) = m.field("size") {
                    if size.as_u64() == 0 {
                        self.findings.push(Finding {
                            severity: Severity::Warning,
                            rule: "ze-zero-byte-copy",
                            message: "zero-byte zeCommandListAppendMemoryCopy".into(),
                            ts: m.ts,
                        });
                    }
                }
            }
            _ => {}
        }
    }

    /// End of trace: flag still-live handles, sort and return findings.
    /// Leaked-handle findings are emitted in handle order so the report
    /// is deterministic across runs.
    pub fn finish(&mut self) -> Vec<Finding> {
        let live_events = std::mem::take(&mut self.live_events);
        let live_modules = std::mem::take(&mut self.live_modules);
        let live_kernels = std::mem::take(&mut self.live_kernels);
        let mut findings = std::mem::take(&mut self.findings);
        let sets = [
            (live_events, "unreleased-event", "event"),
            (live_modules, "unreleased-module", "module"),
            (live_kernels, "unreleased-kernel", "kernel"),
        ];
        for (map, rule, what) in sets {
            let mut leaked: Vec<_> = map.into_iter().collect();
            leaked.sort_unstable();
            for (h, ts) in leaked {
                findings.push(Finding {
                    severity: Severity::Warning,
                    rule,
                    message: format!("{what} {h:#x} created at t={ts}ns was never destroyed"),
                    ts: 0,
                });
            }
        }
        findings.sort_by_key(|f| f.ts);
        findings
    }
}

/// Run all validation rules over a muxed message sequence
/// (compatibility shim over [`Validator`]).
pub fn validate(msgs: &[EventMsg]) -> Vec<Finding> {
    let mut v = Validator::new();
    for m in msgs {
        v.observe(m);
    }
    v.finish()
}

/// Render findings as a report.
pub fn render_report(findings: &[Finding]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let errors = findings.iter().filter(|f| f.severity == Severity::Error).count();
    let warnings = findings.len() - errors;
    let _ = writeln!(out, "validation: {errors} error(s), {warnings} warning(s)");
    for f in findings {
        let tag = match f.severity {
            Severity::Error => "ERROR",
            Severity::Warning => "WARN ",
        };
        let _ = writeln!(out, "[{tag}] {}: {}", f.rule, f.message);
    }
    out
}

/// The validation plugin as a streaming [`AnalysisSink`].
#[derive(Default)]
pub struct ValidateSink {
    validator: Validator,
}

impl ValidateSink {
    /// Empty sink.
    pub fn new() -> Self {
        Self::default()
    }
}

impl AnalysisSink for ValidateSink {
    fn name(&self) -> &'static str {
        "validate"
    }

    fn consume_event(&mut self, m: &EventMsg) {
        self.validator.observe(m);
    }

    fn finish(&mut self) -> Report {
        Report::Text(render_report(&self.validator.finish()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::msg::parse_trace;
    use crate::analysis::muxer::MessageSource;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};

    fn run<F: FnOnce()>(f: F) -> Vec<Finding> {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        f();
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        let msgs: Vec<_> = MessageSource::new(&parsed).cloned().collect();
        validate(&msgs)
    }

    #[test]
    fn uninitialized_pnext_is_flagged() {
        let findings = run(|| {
            let c = class_by_name("lttng_ust_ze:zeDeviceGetProperties_entry").unwrap();
            emit(c, |e| {
                e.ptr(0xde0).ptr(0x7ffe).ptr(0xdeadbeef); // garbage pNext
            });
        });
        assert!(findings.iter().any(|f| f.rule == "ze-uninitialized-pnext"));
        assert_eq!(findings[0].severity, Severity::Error);
    }

    #[test]
    fn null_pnext_is_clean() {
        let findings = run(|| {
            let c = class_by_name("lttng_ust_ze:zeDeviceGetProperties_entry").unwrap();
            emit(c, |e| {
                e.ptr(0xde0).ptr(0x7ffe).ptr(0);
            });
        });
        assert!(findings.is_empty());
    }

    #[test]
    fn unreleased_event_is_flagged_and_released_is_not() {
        let findings = run(|| {
            let cx = class_by_name("lttng_ust_ze:zeEventCreate_exit").unwrap();
            emit(cx, |e| {
                e.u64(0).ptr(0xe001);
            });
            emit(cx, |e| {
                e.u64(0).ptr(0xe002);
            });
            let d = class_by_name("lttng_ust_ze:zeEventDestroy_entry").unwrap();
            emit(d, |e| {
                e.ptr(0xe001);
            });
        });
        let unreleased: Vec<_> =
            findings.iter().filter(|f| f.rule == "unreleased-event").collect();
        assert_eq!(unreleased.len(), 1);
        assert!(unreleased[0].message.contains("0xe002"));
    }

    #[test]
    fn list_reclose_without_reset_is_flagged() {
        let findings = run(|| {
            let close = class_by_name("lttng_ust_ze:zeCommandListClose_entry").unwrap();
            emit(close, |e| {
                e.ptr(0x1150);
            });
            emit(close, |e| {
                e.ptr(0x1150);
            });
        });
        assert!(findings.iter().any(|f| f.rule == "ze-list-not-reset"));
    }

    #[test]
    fn reset_between_closes_is_clean() {
        let findings = run(|| {
            let close = class_by_name("lttng_ust_ze:zeCommandListClose_entry").unwrap();
            let reset = class_by_name("lttng_ust_ze:zeCommandListReset_entry").unwrap();
            emit(close, |e| {
                e.ptr(0x1150);
            });
            emit(reset, |e| {
                e.ptr(0x1150);
            });
            emit(close, |e| {
                e.ptr(0x1150);
            });
        });
        assert!(!findings.iter().any(|f| f.rule == "ze-list-not-reset"));
    }

    #[test]
    fn zero_byte_copy_warns() {
        let findings = run(|| {
            let c = class_by_name("lttng_ust_ze:zeCommandListAppendMemoryCopy_entry").unwrap();
            emit(c, |e| {
                e.ptr(1).ptr(2).ptr(3).u64(0).ptr(0).u64(0).ptr(0);
            });
        });
        assert!(findings.iter().any(|f| f.rule == "ze-zero-byte-copy"));
    }

    #[test]
    fn streaming_validator_matches_eager_validate() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let cx = class_by_name("lttng_ust_ze:zeEventCreate_exit").unwrap();
        emit(cx, |e| {
            e.u64(0).ptr(0xe00f);
        });
        let c = class_by_name("lttng_ust_ze:zeDeviceGetProperties_entry").unwrap();
        emit(c, |e| {
            e.ptr(0xde0).ptr(0x7ffe).ptr(0xbad);
        });
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        let msgs: Vec<_> = MessageSource::new(&parsed).cloned().collect();
        let eager = render_report(&validate(&msgs));
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(ValidateSink::new())];
        let reports = crate::analysis::sink::run_pipeline(&parsed, &mut sinks);
        assert_eq!(reports[0].payload().unwrap(), eager);
    }

    #[test]
    fn report_renders_counts() {
        let findings = vec![Finding {
            severity: Severity::Error,
            rule: "x",
            message: "m".into(),
            ts: 0,
        }];
        let r = render_report(&findings);
        assert!(r.contains("1 error(s), 0 warning(s)"));
    }
}
