//! Metababel-style dispatch: plugins as callback collections.
//!
//! THAPI's Metababel "attaches user-defined callbacks to trace events
//! (generated automatically from the LTTng trace model)… all the plugins
//! are collections of callbacks that are executed when they receive
//! events." [`Graph`] is that: register callbacks on exact names or
//! substring patterns, then push a muxed message sequence through.

use super::msg::EventMsg;
use std::collections::HashMap;

type Callback<'a> = Box<dyn FnMut(&EventMsg) + 'a>;

/// A processing graph: muxed source -> pattern-dispatched callbacks.
#[derive(Default)]
pub struct Graph<'a> {
    exact: HashMap<String, Vec<usize>>,
    patterns: Vec<(String, usize)>,
    all: Vec<usize>,
    callbacks: Vec<Callback<'a>>,
}

impl<'a> Graph<'a> {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach a callback to an exact event name.
    pub fn on(&mut self, name: &str, cb: impl FnMut(&EventMsg) + 'a) -> &mut Self {
        let id = self.callbacks.len();
        self.callbacks.push(Box::new(cb));
        self.exact.entry(name.to_string()).or_default().push(id);
        self
    }

    /// Attach a callback to every event whose name contains `pattern`.
    pub fn on_matching(&mut self, pattern: &str, cb: impl FnMut(&EventMsg) + 'a) -> &mut Self {
        let id = self.callbacks.len();
        self.callbacks.push(Box::new(cb));
        self.patterns.push((pattern.to_string(), id));
        self
    }

    /// Attach a callback to every event.
    pub fn on_all(&mut self, cb: impl FnMut(&EventMsg) + 'a) -> &mut Self {
        let id = self.callbacks.len();
        self.callbacks.push(Box::new(cb));
        self.all.push(id);
        self
    }

    /// Dispatch one message to every matching callback.
    ///
    /// The registration tables (`exact`/`patterns`/`all`) and the
    /// callback vector are disjoint fields, so destructuring `self`
    /// splits the borrow: the id lists stay immutably borrowed while
    /// individual callbacks are called mutably — no per-event clone of
    /// any callback-id list on the hot path.
    pub fn dispatch(&mut self, m: &EventMsg) {
        let Graph { exact, patterns, all, callbacks } = self;
        if let Some(ids) = exact.get(m.class.name.as_str()) {
            for &id in ids {
                (callbacks[id])(m);
            }
        }
        for (pat, id) in patterns.iter() {
            if m.class.name.contains(pat.as_str()) {
                (callbacks[*id])(m);
            }
        }
        for &id in all.iter() {
            (callbacks[id])(m);
        }
    }

    /// Push a message sequence through the graph. Accepts any borrowed
    /// message iterator — a `&[EventMsg]` slice or a lazy
    /// [`super::muxer::MessageSource`].
    pub fn run<'m>(&mut self, msgs: impl IntoIterator<Item = &'m EventMsg>) {
        for m in msgs {
            self.dispatch(m);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::msg::parse_trace;
    use crate::analysis::muxer::MessageSource;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};
    use std::cell::Cell;

    fn sample_msgs() -> Vec<EventMsg> {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let init = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let init_x = class_by_name("lttng_ust_ze:zeInit_exit").unwrap();
        let cu = class_by_name("lttng_ust_cuda:cuInit_entry").unwrap();
        emit(init, |e| {
            e.u64(0);
        });
        emit(init_x, |e| {
            e.u64(0);
        });
        emit(cu, |e| {
            e.u64(0);
        });
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        MessageSource::new(&parsed).cloned().collect()
    }

    #[test]
    fn dispatch_by_exact_name_and_pattern() {
        let msgs = sample_msgs();
        let exact_hits = Cell::new(0);
        let ze_hits = Cell::new(0);
        let all_hits = Cell::new(0);
        let mut g = Graph::new();
        g.on("lttng_ust_ze:zeInit_entry", |_| exact_hits.set(exact_hits.get() + 1));
        g.on_matching("lttng_ust_ze", |_| ze_hits.set(ze_hits.get() + 1));
        g.on_all(|_| all_hits.set(all_hits.get() + 1));
        g.run(&msgs);
        assert_eq!(exact_hits.get(), 1);
        assert_eq!(ze_hits.get(), 2);
        assert_eq!(all_hits.get(), 3);
    }

    #[test]
    fn graph_runs_from_lazy_message_source() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let init = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        for _ in 0..3 {
            emit(init, |e| {
                e.u64(0);
            });
        }
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        let hits = Cell::new(0);
        let mut g = Graph::new();
        g.on("lttng_ust_ze:zeInit_entry", |_| hits.set(hits.get() + 1));
        g.run(crate::analysis::muxer::MessageSource::new(&parsed));
        assert_eq!(hits.get(), 3);
    }
}
