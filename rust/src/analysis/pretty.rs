//! Pretty Print plugin: babeltrace2-style text output.
//!
//! The formatting is *generated*: every field of every event is rendered
//! from the trace-model descriptor (name + wire type), so new tracepoints
//! pretty-print with zero plugin changes — the paper's "plugins generated
//! automatically from the API model". Output shape mirrors the §1.1
//! THAPI example: timestamp, hostname, vpid/vtid, event name, then the
//! full field list (pointers in hex).

use super::msg::EventMsg;
use super::sink::{AnalysisSink, Report};
use std::fmt::Write as _;

/// Format one event.
pub fn format_event(m: &EventMsg) -> String {
    let mut out = String::new();
    let secs = m.ts / 1_000_000_000;
    let nanos = m.ts % 1_000_000_000;
    let _ = write!(
        out,
        "[{secs:02}.{nanos:09}] {}: vpid: {}, vtid: {}, {}: {{ ",
        m.hostname, m.rank, m.tid, m.class.name
    );
    for (i, (f, v)) in m.class.fields.iter().zip(&m.fields).enumerate() {
        if i > 0 {
            let _ = write!(out, ", ");
        }
        let _ = write!(out, "{}: {}", f.name, v.render());
    }
    let _ = write!(out, " }}");
    out
}

/// Pretty-print a muxed message sequence.
pub fn pretty_print(msgs: &[EventMsg]) -> String {
    let mut out = String::with_capacity(msgs.len() * 120);
    for m in msgs {
        out.push_str(&format_event(m));
        out.push('\n');
    }
    out
}

/// The Pretty Print plugin as a streaming [`AnalysisSink`]: each message
/// is formatted the moment it flows past; only the rendered text (the
/// output itself) is retained.
#[derive(Default)]
pub struct PrettySink {
    out: String,
}

impl PrettySink {
    /// Empty sink.
    pub fn new() -> Self {
        Self::default()
    }
}

impl AnalysisSink for PrettySink {
    fn name(&self) -> &'static str {
        "pretty"
    }

    fn consume_event(&mut self, m: &EventMsg) {
        self.out.push_str(&format_event(m));
        self.out.push('\n');
    }

    fn finish(&mut self) -> Report {
        Report::Text(std::mem::take(&mut self.out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::msg::parse_trace;
    use crate::analysis::muxer::MessageSource;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};

    #[test]
    fn memcpy_event_renders_like_paper_example() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let class = class_by_name("lttng_ust_ze:zeCommandListAppendMemoryCopy_entry").unwrap();
        emit(class, |e| {
            e.ptr(0x1150_0000_0010)
                .ptr(0xff00_0000_0000_1000) // device dst
                .ptr(0x0000_7f00_0000_2000) // host src
                .u64(1 << 20)
                .ptr(0)
                .u64(0)
                .ptr(0);
        });
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        let msgs: Vec<_> = MessageSource::new(&parsed).cloned().collect();
        let text = pretty_print(&msgs);
        // The paper's point: source/dest pointers + size are all visible,
        // and the address spaces are readable off the hex values.
        assert!(text.contains("zeCommandListAppendMemoryCopy_entry"));
        assert!(text.contains("dstptr: 0xff00000000001000"));
        assert!(text.contains("srcptr: 0x00007f0000002000"));
        assert!(text.contains("size: 1048576"));
        assert!(text.contains("vpid:"));
        assert!(text.contains("vtid:"));
    }

    #[test]
    fn every_field_of_every_class_renders() {
        // generated-plugin property: formatting never panics for any class
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let exitc = class_by_name("lttng_ust_cuda:cuMemGetInfo_exit").unwrap();
        emit(exitc, |e| {
            e.u64(0).u64(48 << 30).u64(64 << 30);
        });
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        let msgs: Vec<_> = MessageSource::new(&parsed).cloned().collect();
        let text = pretty_print(&msgs);
        assert!(text.contains("*free: 51539607552"));
        assert!(text.contains("*total: 68719476736"));
    }
}
