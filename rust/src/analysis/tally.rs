//! Tally plugin: the summary table of the paper's §4.3.
//!
//! Aggregates host API intervals (and device commands from the profiling
//! events) into per-function rows: Time, Time(%), Calls, Average, Min,
//! Max — sorted by total time, with the backend/hostname/process/thread
//! counts header. Tallies are mergeable (the §3.7 aggregation protocol
//! ships serialized tallies from local masters to the global master) and
//! round-trip through a compact text serialization.

use super::interval::{Interval, IntervalTracker};
use super::msg::{EventMsg, ParsedTrace};
use super::muxer::MessageSource;
use super::sink::{AnalysisSink, Report};
use anyhow::{Context, Result};
use std::collections::{BTreeMap, HashSet};
use std::fmt::Write as _;

/// One aggregated row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TallyRow {
    /// API function (host) or device command name.
    pub name: String,
    /// Backend label.
    pub api: String,
    /// Total time, ns.
    pub time_ns: u64,
    /// Call count.
    pub calls: u64,
    /// Min duration, ns.
    pub min_ns: u64,
    /// Max duration, ns.
    pub max_ns: u64,
}

impl TallyRow {
    /// Average duration in ns.
    pub fn avg_ns(&self) -> u64 {
        if self.calls == 0 {
            0
        } else {
            self.time_ns / self.calls
        }
    }

    fn absorb(&mut self, dur: u64) {
        self.time_ns += dur;
        self.calls += 1;
        self.min_ns = self.min_ns.min(dur);
        self.max_ns = self.max_ns.max(dur);
    }

    fn merge(&mut self, other: &TallyRow) {
        self.time_ns += other.time_ns;
        self.calls += other.calls;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// The tally: host and device sections plus context counts.
#[derive(Debug, Clone, Default)]
pub struct Tally {
    /// Host API rows keyed by (api, name).
    pub host: BTreeMap<(String, String), TallyRow>,
    /// Device command rows keyed by name (kernel name / memcpy / barrier).
    pub device: BTreeMap<String, TallyRow>,
    /// Distinct hostnames.
    pub hostnames: HashSet<String>,
    /// Distinct ranks ("processes").
    pub processes: HashSet<u32>,
    /// Distinct (rank, tid) threads.
    pub threads: HashSet<(u32, u32)>,
}

impl Tally {
    /// Absorb one host API span (streaming sink stage).
    pub fn add_interval(&mut self, iv: &Interval) {
        self.hostnames.insert(iv.hostname.to_string());
        self.processes.insert(iv.rank);
        self.threads.insert((iv.rank, iv.tid));
        let key = (iv.api.clone(), iv.name.clone());
        let dur = iv.duration();
        self.host
            .entry(key)
            .or_insert_with(|| TallyRow {
                name: iv.name.clone(),
                api: iv.api.clone(),
                time_ns: 0,
                calls: 0,
                min_ns: u64::MAX,
                max_ns: 0,
            })
            .absorb(dur);
    }

    /// Absorb one raw message: device rows come from the
    /// `command_completed` profiling events (streaming sink stage).
    pub fn add_event(&mut self, m: &EventMsg) {
        if m.class.name != "lttng_ust_profiling:command_completed" {
            return;
        }
        let kind = m.field("kind").map(|v| v.as_str().to_string()).unwrap_or_default();
        let kname = m.field("name").map(|v| v.as_str().to_string()).unwrap_or_default();
        let label = if kind == "kernel" { kname } else { kind.clone() };
        if label.is_empty() || label == "barrier" {
            return;
        }
        let start = m.field("ts_start").map(|v| v.as_u64()).unwrap_or(0);
        let end = m.field("ts_end").map(|v| v.as_u64()).unwrap_or(0);
        self.device
            .entry(label.clone())
            .or_insert_with(|| TallyRow {
                name: label,
                api: "GPU".into(),
                time_ns: 0,
                calls: 0,
                min_ns: u64::MAX,
                max_ns: 0,
            })
            .absorb(end.saturating_sub(start));
    }

    /// Build from paired host intervals and (optionally) profiling events
    /// (compatibility shim over the streaming `add_*` methods).
    pub fn build(intervals: &[Interval], profiling: &[EventMsg]) -> Self {
        let mut t = Tally::default();
        for iv in intervals {
            t.add_interval(iv);
        }
        for m in profiling {
            t.add_event(m);
        }
        t
    }

    /// Build straight from a parsed trace in one streaming pass: lazy
    /// muxing + incremental interval pairing, no `Vec<EventMsg>` and no
    /// interval buffer (row aggregation is order-independent).
    pub fn from_parsed(parsed: &ParsedTrace) -> Self {
        let mut t = Tally::default();
        let mut tracker = IntervalTracker::new();
        for m in MessageSource::new(parsed) {
            t.add_event(m);
            tracker.push(m, |iv| t.add_interval(&iv));
        }
        tracker.finish(|iv| t.add_interval(&iv));
        t
    }

    /// Merge another tally into this one (aggregation tree, §3.7).
    pub fn merge(&mut self, other: &Tally) {
        for (k, row) in &other.host {
            match self.host.get_mut(k) {
                Some(r) => r.merge(row),
                None => {
                    self.host.insert(k.clone(), row.clone());
                }
            }
        }
        for (k, row) in &other.device {
            match self.device.get_mut(k) {
                Some(r) => r.merge(row),
                None => {
                    self.device.insert(k.clone(), row.clone());
                }
            }
        }
        self.hostnames.extend(other.hostnames.iter().cloned());
        self.processes.extend(other.processes.iter().copied());
        self.threads.extend(other.threads.iter().copied());
    }

    /// Total host time (denominator of Time(%)).
    pub fn total_host_ns(&self) -> u64 {
        self.host.values().map(|r| r.time_ns).sum()
    }

    /// Backend -> distinct-function counts (the "BACKEND_HIP 1 | BACKEND_ZE 2"
    /// header of the §4.3 table).
    pub fn backend_counts(&self) -> BTreeMap<String, usize> {
        let mut m = BTreeMap::new();
        for (api, _) in self.host.keys() {
            *m.entry(api.clone()).or_insert(0) += 1;
        }
        m
    }

    /// Host rows sorted by total time, descending.
    pub fn host_rows(&self) -> Vec<&TallyRow> {
        let mut rows: Vec<_> = self.host.values().collect();
        rows.sort_by(|a, b| b.time_ns.cmp(&a.time_ns));
        rows
    }

    /// Device rows sorted by total time, descending.
    pub fn device_rows(&self) -> Vec<&TallyRow> {
        let mut rows: Vec<_> = self.device.values().collect();
        rows.sort_by(|a, b| b.time_ns.cmp(&a.time_ns));
        rows
    }

    /// Render the §4.3-style table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut header = String::new();
        for (api, n) in self.backend_counts() {
            let _ = write!(header, "BACKEND_{api} {n} | ");
        }
        let _ = writeln!(
            out,
            "{header}{} Hostnames | {} Processes | {} Threads",
            self.hostnames.len(),
            self.processes.len(),
            self.threads.len()
        );
        let total = self.total_host_ns().max(1);
        let _ = writeln!(
            out,
            "{:<38} | {:>10} | {:>8} | {:>9} | {:>10} | {:>10} | {:>10} |",
            "Name", "Time", "Time(%)", "Calls", "Average", "Min", "Max"
        );
        for r in self.host_rows() {
            let _ = writeln!(
                out,
                "{:<38} | {:>10} | {:>7.2}% | {:>9} | {:>10} | {:>10} | {:>10} |",
                r.name,
                fmt_ns(r.time_ns),
                r.time_ns as f64 * 100.0 / total as f64,
                r.calls,
                fmt_ns(r.avg_ns()),
                fmt_ns(r.min_ns),
                fmt_ns(r.max_ns),
            );
        }
        if !self.device.is_empty() {
            let _ = writeln!(out, "{:-<120}", "");
            let _ = writeln!(out, "Device profiling:");
            for r in self.device_rows() {
                let _ = writeln!(
                    out,
                    "{:<38} | {:>10} | {:>8} | {:>9} | {:>10} | {:>10} | {:>10} |",
                    r.name,
                    fmt_ns(r.time_ns),
                    "",
                    r.calls,
                    fmt_ns(r.avg_ns()),
                    fmt_ns(r.min_ns),
                    fmt_ns(r.max_ns),
                );
            }
        }
        out
    }

    /// Compact serialization for the aggregation protocol (§3.7).
    pub fn serialize(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "tally v1 hosts={} procs={} threads={}",
            self.hostnames.iter().cloned().collect::<Vec<_>>().join(","),
            self.processes.iter().map(|p| p.to_string()).collect::<Vec<_>>().join(","),
            self.threads.iter().map(|(r, t)| format!("{r}.{t}")).collect::<Vec<_>>().join(",")
        );
        for r in self.host.values() {
            let _ = writeln!(
                out,
                "h {} {} {} {} {} {}",
                r.api, r.name, r.time_ns, r.calls, r.min_ns, r.max_ns
            );
        }
        for r in self.device.values() {
            let _ = writeln!(
                out,
                "d {} {} {} {} {} {}",
                r.api, r.name, r.time_ns, r.calls, r.min_ns, r.max_ns
            );
        }
        out
    }

    /// Parse a serialized tally.
    pub fn deserialize(text: &str) -> Result<Self> {
        let mut t = Tally::default();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("tally v1 ") {
                for part in rest.split_whitespace() {
                    let (k, v) = part.split_once('=').context("bad header")?;
                    if v.is_empty() {
                        continue;
                    }
                    match k {
                        "hosts" => t.hostnames.extend(v.split(',').map(String::from)),
                        "procs" => {
                            for p in v.split(',') {
                                t.processes.insert(p.parse()?);
                            }
                        }
                        "threads" => {
                            for p in v.split(',') {
                                let (r, tid) = p.split_once('.').context("bad thread")?;
                                t.threads.insert((r.parse()?, tid.parse()?));
                            }
                        }
                        _ => {}
                    }
                }
                continue;
            }
            let mut it = line.split_whitespace();
            let Some(tag) = it.next() else { continue };
            if tag != "h" && tag != "d" {
                continue;
            }
            let api = it.next().context("api")?.to_string();
            let name = it.next().context("name")?.to_string();
            let row = TallyRow {
                api: api.clone(),
                name: name.clone(),
                time_ns: it.next().context("time")?.parse()?,
                calls: it.next().context("calls")?.parse()?,
                min_ns: it.next().context("min")?.parse()?,
                max_ns: it.next().context("max")?.parse()?,
            };
            if tag == "h" {
                t.host.insert((api, name), row);
            } else {
                t.device.insert(name, row);
            }
        }
        Ok(t)
    }
}

/// The Tally plugin as a streaming [`AnalysisSink`]: host rows from the
/// interval filter, device rows from profiling events, rendered §4.3
/// table at finish. State is O(distinct API functions), not trace-sized.
#[derive(Default)]
pub struct TallySink {
    tally: Tally,
}

impl TallySink {
    /// Empty sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// The accumulated tally so far (final after the pipeline ends).
    pub fn tally(&self) -> &Tally {
        &self.tally
    }

    /// Take the accumulated tally out of the sink.
    pub fn into_tally(self) -> Tally {
        self.tally
    }
}

impl AnalysisSink for TallySink {
    fn name(&self) -> &'static str {
        "tally"
    }

    fn consume_event(&mut self, m: &EventMsg) {
        self.tally.add_event(m);
    }

    fn consume_interval(&mut self, iv: &Interval) {
        self.tally.add_interval(iv);
    }

    /// Live-mode refresh: render the tally accumulated *so far*. Rows
    /// are aggregates, so a snapshot is cheap and leaves the final
    /// `finish` state untouched.
    fn refresh(&mut self) -> Option<Report> {
        Some(Report::Text(self.tally.render()))
    }

    fn finish(&mut self) -> Report {
        Report::Text(self.tally.render())
    }
}

/// Humanize a nanosecond quantity the way iprof does (471.80ns, 3.56ms,
/// 4.73s).
pub fn fmt_ns(ns: u64) -> String {
    let ns = ns as f64;
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::interval::intervals_of;
    use crate::analysis::msg::parse_trace;
    use crate::analysis::muxer::MessageSource;
    use crate::model::class_by_name;
    use crate::tracer::btf::collect;
    use crate::tracer::session::test_support;
    use crate::tracer::{emit, install_session, uninstall_session, SessionConfig};

    fn sample_tally() -> Tally {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let e = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let x = class_by_name("lttng_ust_ze:zeInit_exit").unwrap();
        for _ in 0..10 {
            emit(e, |en| {
                en.u64(0);
            });
            emit(x, |en| {
                en.u64(0);
            });
        }
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        Tally::from_parsed(&parse_trace(&trace).unwrap())
    }

    #[test]
    fn eager_build_matches_streaming_from_parsed() {
        let _g = test_support::lock();
        install_session(SessionConfig::default());
        let e = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
        let x = class_by_name("lttng_ust_ze:zeInit_exit").unwrap();
        for _ in 0..7 {
            emit(e, |en| {
                en.u64(0);
            });
            emit(x, |en| {
                en.u64(0);
            });
        }
        let session = uninstall_session().unwrap();
        let trace = collect(&session, &[]);
        let parsed = parse_trace(&trace).unwrap();
        // materialized reference: owned merge + span vector through the
        // eager Tally::build entry point
        let msgs: Vec<_> = MessageSource::new(&parsed).cloned().collect();
        let eager = Tally::build(&intervals_of(&parsed), &msgs);
        let streaming = Tally::from_parsed(&parsed);
        assert_eq!(streaming.host, eager.host);
        assert_eq!(streaming.device, eager.device);
        assert_eq!(streaming.render(), eager.render());
    }

    #[test]
    fn build_counts_calls_and_times() {
        let t = sample_tally();
        let row = &t.host[&("ZE".to_string(), "zeInit".to_string())];
        assert_eq!(row.calls, 10);
        assert!(row.min_ns <= row.avg_ns() && row.avg_ns() <= row.max_ns);
        assert_eq!(t.processes.len(), 1);
    }

    #[test]
    fn render_contains_table_columns() {
        let t = sample_tally();
        let s = t.render();
        assert!(s.contains("BACKEND_ZE 1"));
        assert!(s.contains("Time(%)"));
        assert!(s.contains("zeInit"));
        assert!(s.contains("Hostnames"));
    }

    #[test]
    fn serialize_roundtrip_preserves_rows() {
        let t = sample_tally();
        let s = t.serialize();
        let back = Tally::deserialize(&s).unwrap();
        assert_eq!(t.host, back.host);
        assert_eq!(t.hostnames, back.hostnames);
        assert_eq!(t.threads, back.threads);
    }

    #[test]
    fn merge_adds_counts() {
        let t1 = sample_tally();
        let t2 = sample_tally();
        let mut m = t1.clone();
        m.merge(&t2);
        let row = &m.host[&("ZE".to_string(), "zeInit".to_string())];
        assert_eq!(row.calls, 20);
        assert_eq!(
            row.time_ns,
            t1.host.values().next().unwrap().time_ns + t2.host.values().next().unwrap().time_ns
        );
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500), "500ns");
        assert_eq!(fmt_ns(4_730_000_000), "4.73s");
        assert_eq!(fmt_ns(3_560_000), "3.56ms");
        assert_eq!(fmt_ns(471_800), "471.80us");
    }
}
