//! Trace analysis: the Babeltrace2 + Metababel substitute (paper §3.4).
//!
//! A BTF trace is parsed offline (never touching the live registry) and
//! pushed through a source → muxer → filter → sink graph:
//!
//! * [`msg`] — the message model: decoded events with stream context.
//! * [`muxer`] — k-way merge of per-thread streams by timestamp (the
//!   "Muxer plugin for serializing messages by time").
//! * [`graph`] — Metababel-style callback dispatch: plugins are
//!   collections of callbacks attached to event-name patterns.
//! * [`interval`] — pairs `_entry`/`_exit` events into host spans per
//!   (rank, thread), handling nesting (HIP-on-ZE layering).
//! * [`pretty`] — Pretty Print: babeltrace2-style text, formatting every
//!   field from the trace-model descriptors (the generated plugin).
//! * [`tally`] — Tally: the §4.3 summary table (time/%/calls/avg/min/max
//!   per API call, host and device sections, backend totals).
//! * [`timeline`] — Timeline: Perfetto-compatible chrome-trace JSON with
//!   host rows, device rows and telemetry counter rows (Fig. 5/6).
//! * [`validate`] — the §4.2 post-mortem validation plugin (uninitialized
//!   `pNext`, unreleased events, non-reset command lists, ...).

pub mod graph;
pub mod interval;
pub mod msg;
pub mod muxer;
pub mod pretty;
pub mod tally;
pub mod timeline;
pub mod validate;

pub use graph::Graph;
pub use interval::{pair_intervals, Interval};
pub use msg::{parse_trace, EventMsg, ParsedTrace};
pub use muxer::mux;
pub use pretty::pretty_print;
pub use tally::{Tally, TallyRow};
pub use timeline::timeline_json;
pub use validate::{validate, Finding, Severity};
