//! Trace analysis: the Babeltrace2 + Metababel substitute (paper §3.4).
//!
//! A BTF trace is parsed offline (never touching the live registry) and
//! pushed through a **streaming** source → muxer → filter → sink graph in
//! a single pass:
//!
//! * [`msg`] — the message model: decoded events with stream context.
//! * [`muxer`] — [`MessageSource`], the lazy k-way merge of per-thread
//!   streams by timestamp (the "Muxer plugin for serializing messages by
//!   time"); yields borrowed `&EventMsg`, no per-event clone.
//! * [`interval`] — [`IntervalTracker`], the filter stage: pairs
//!   `_entry`/`_exit` into host spans per (rank, thread) as messages
//!   flow, handling nesting (HIP-on-ZE layering), and emits each
//!   completed [`Interval`] downstream immediately.
//! * [`sink`] — the [`AnalysisSink`] contract plus [`run_pipeline`]: any
//!   set of sinks fans out from one pass over the trace
//!   (`iprof -a tally,timeline,validate` decodes and merges once).
//! * [`graph`] — Metababel-style callback dispatch: plugins are
//!   collections of callbacks attached to event-name patterns.
//! * [`pretty`] — Pretty Print: babeltrace2-style text, formatting every
//!   field from the trace-model descriptors (the generated plugin).
//! * [`tally`] — Tally: the §4.3 summary table (time/%/calls/avg/min/max
//!   per API call, host and device sections, backend totals).
//! * [`timeline`] — Timeline: Perfetto-compatible chrome-trace JSON with
//!   host rows, device rows and telemetry counter rows (Fig. 5/6).
//! * [`validate`] — the §4.2 post-mortem validation plugin (uninitialized
//!   `pNext`, unreleased events, non-reset command lists, ...).
//!
//! The eager renderers ([`pretty_print`], [`timeline_json`],
//! [`Tally::build`], [`validate()`](validate::validate)) remain as
//! independent second implementations over owned slices — the golden
//! suite in `rust/tests/streaming.rs` pins the streaming sinks
//! byte-for-byte against them. (The seed's `mux`/`pair_intervals`
//! materializing shims went through deprecation in PR 2 and are now
//! deleted; [`MessageSource`] + [`intervals_of`] cover every call site.)
//! The same graph also runs **on-line** while the application executes:
//! [`crate::live`] feeds the [`PipelineDriver`] core from the tracing
//! consumer thread through bounded watermarked channels, and
//! [`crate::remote`] extends that over a socket. See
//! `rust/ARCHITECTURE.md` for how to write a new sink and for the live
//! and remote designs.

pub mod graph;
pub mod interval;
pub mod msg;
pub mod muxer;
pub mod pretty;
pub mod sink;
pub mod tally;
pub mod timeline;
pub mod validate;

pub use graph::Graph;
pub use interval::{intervals_of, Interval, IntervalTracker};
pub use msg::{parse_trace, EventMsg, ParsedTrace};
pub use muxer::MessageSource;
pub use pretty::{pretty_print, PrettySink};
pub use sink::{run_pipeline, AnalysisSink, PipelineDriver, Report};
pub use tally::{Tally, TallyRow, TallySink};
pub use timeline::{timeline_json, TimelineSink};
pub use validate::{validate, Finding, Severity, ValidateSink, Validator};
