//! Minimal property-testing helper (proptest substitute).
//!
//! `check(cases, seed, f)` runs `f` against `cases` generated inputs drawn
//! from a deterministic [`Rng`]; on failure it retries with a binary-ish
//! shrink of the failing seed space by re-reporting the exact seed, so a
//! failing case is always reproducible from the panic message.

use super::rng::Rng;

/// Run `f` for `cases` deterministic cases. `f` gets a fresh [`Rng`] per
/// case; panic (assert) inside `f` to signal failure. The per-case seed is
/// printed on failure for reproduction.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: u32, seed: u64, f: F) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(case_seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed (case {case}, seed {case_seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, 1, |rng| {
            let v = rng.below(100);
            assert!(v < 100);
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(50, 2, |rng| {
            assert!(rng.below(10) < 5, "too big");
        });
    }
}
