//! Small shared utilities: deterministic PRNG and a property-test helper.

pub mod prop;
pub mod rng;

pub use rng::Rng;
