//! Deterministic splitmix64-based PRNG.
//!
//! No `rand` crate is available offline; this is the standard splitmix64
//! generator (public-domain constants), good enough for workload jitter,
//! synthetic data and property-test case generation. Deterministic by seed
//! so every bench and test is reproducible.

/// Splitmix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds give equal sequences.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform u64 in `[0, bound)`. `bound` must be nonzero.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire-style multiply-shift; slight bias is irrelevant here.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a f32 buffer with uniform values in [-1, 1).
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.f32_in(-1.0, 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_covers_all_values() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.range(0, 8)] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }
}
