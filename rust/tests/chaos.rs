//! Seeded chaos sweep over the whole THRL stack — the headline test of
//! `thapi::testkit`.
//!
//! Each seed expands into a full scenario (leaf publishers, optional
//! relays, a root attach, composed byte-deterministic faults), runs
//! **twice** on the real publisher/broadcaster/fan-in/relay code, and
//! must satisfy both oracles: conservation (every published event is
//! merged once or booked in exactly one ledger) and determinism (both
//! runs agree exactly). Lossless runs must additionally match the
//! post-mortem golden — the answer an offline merge of the same events
//! gives.
//!
//! Knobs (all honored by every test that sweeps):
//!
//! * `THAPI_CHAOS_SEEDS=3,17` — run exactly these seeds. This is the
//!   one-command repro a failing sweep prints.
//! * `THAPI_CHAOS_QUICK=1` — CI-sized sweep (8 seeds instead of 24).

use std::sync::mpsc;
use std::time::Duration;
use thapi::remote::frame::T_EOS;
use thapi::testkit::{
    check_conservation, check_determinism, event_len, hello_wire_len, post_mortem_golden,
    total_known_loss, EventSpec, FaultSpec, LeafSpec, RelaySpec, RunReport, Scenario,
};

/// The sweep's seed list, env-overridable for repro and CI sizing.
fn seeds() -> Vec<u64> {
    if let Ok(list) = std::env::var("THAPI_CHAOS_SEEDS") {
        let seeds: Vec<u64> = list
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| t.parse().unwrap_or_else(|_| panic!("THAPI_CHAOS_SEEDS: bad seed {t:?}")))
            .collect();
        assert!(!seeds.is_empty(), "THAPI_CHAOS_SEEDS is set but names no seeds");
        return seeds;
    }
    if std::env::var("THAPI_CHAOS_QUICK").is_ok() {
        (0..8).collect()
    } else {
        (0..24).collect()
    }
}

/// The one-command repro line every failure prints.
fn repro(seed: u64) -> String {
    format!("repro: THAPI_CHAOS_SEEDS={seed} cargo test --test chaos -- seeded_sweep")
}

/// Run a scenario under a watchdog: a hung or panicked run fails with
/// the seed and the full scenario script, never a stuck test binary.
fn run_watched(sc: &Scenario) -> RunReport {
    let (tx, rx) = mpsc::channel();
    let owned = sc.clone();
    std::thread::spawn(move || {
        let _ = tx.send(owned.run());
    });
    match rx.recv_timeout(Duration::from_secs(60)) {
        Ok(rep) => rep,
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("chaos scenario HUNG (seed {})\n{}\n{sc}", sc.seed, repro(sc.seed))
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => panic!(
            "chaos scenario PANICKED (seed {}) — see stderr above\n{}\n{sc}",
            sc.seed,
            repro(sc.seed)
        ),
    }
}

/// A handcrafted fault-free leaf for the directed tests.
fn leaf_spec(host: &str, wire: u32, rank: u32, streams: &[&[u64]]) -> LeafSpec {
    LeafSpec {
        hostname: host.to_string(),
        epoch: 0xE0 + rank as u64 + 1,
        wire,
        resume_buffer: 1 << 20,
        streams: streams
            .iter()
            .enumerate()
            .map(|(j, ts)| {
                ts.iter().map(|&t| EventSpec { ts: t, rank, tid: j as u32 + 1 }).collect()
            })
            .collect(),
        serve_faults: Vec::new(),
        redial_refusals: Vec::new(),
    }
}

// ---------------------------------------------------------------------------
// The headline sweep
// ---------------------------------------------------------------------------

#[test]
fn seeded_sweep_holds_conservation_determinism_and_golden() {
    for seed in seeds() {
        let sc = Scenario::generate(seed);
        let r1 = run_watched(&sc);
        let r2 = run_watched(&sc);
        if let Err(e) = check_conservation(&sc, &r1) {
            panic!("conservation violated (seed {seed}, run 1):\n{e}\n{}\n{sc}", repro(seed));
        }
        if let Err(e) = check_conservation(&sc, &r2) {
            panic!("conservation violated (seed {seed}, run 2):\n{e}\n{}\n{sc}", repro(seed));
        }
        if let Err(e) = check_determinism(&r1, &r2) {
            panic!("determinism violated (seed {seed}):\n{e}\n{}\n{sc}", repro(seed));
        }
        // lossless runs owe the exact offline answer, not just a
        // conserved one
        if total_known_loss(&r1) == 0 {
            let golden = post_mortem_golden(&sc);
            for (ai, attach) in r1.attaches.iter().enumerate() {
                assert_eq!(
                    attach.merged,
                    golden,
                    "lossless run diverged from the post-mortem golden \
                     (seed {seed}, attach {ai})\n{}\n{sc}",
                    repro(seed)
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Directed scenarios: one pinned instance of each oracle clause
// ---------------------------------------------------------------------------

/// Fault-free flat topology: the live chaos path must equal the
/// offline merge byte for byte.
#[test]
fn fault_free_run_matches_the_post_mortem_golden() {
    let sc = Scenario {
        seed: 1000,
        leaves: vec![
            leaf_spec("alpha", 2, 0, &[&[10, 14, 18, 22], &[12, 16]]),
            leaf_spec("beta", 3, 1, &[&[11, 15, 19, 23, 27]]),
        ],
        relays: Vec::new(),
        direct: vec![0, 1],
        root_attaches: 1,
        depth: 64,
    };
    let rep = run_watched(&sc);
    check_conservation(&sc, &rep).unwrap();
    assert_eq!(total_known_loss(&rep), 0);
    assert_eq!(rep.attaches[0].merged, post_mortem_golden(&sc));
}

/// A kill against a tight replay ring: the outage MUST cost events,
/// and the loss appears as one exact, agreed-on gap ledger — at the
/// leaf publisher, at the root origin, and in the merged count — while
/// a rerun reproduces the identical gap.
#[test]
fn tight_ring_kill_books_an_exact_gap_ledger() {
    let ev = event_len();
    let n = 40u64;
    let ts: Vec<u64> = (0..n).map(|i| 10 + i * 5).collect();
    let mut leaf = leaf_spec("lossy", 2, 0, &[&ts]);
    leaf.resume_buffer = 3 * ev; // a 3-event ring cannot cover the outage
    leaf.serve_faults = vec![FaultSpec {
        kill_at_byte: Some(8 + hello_wire_len("lossy") + 20 * ev),
        ..Default::default()
    }];
    let sc = Scenario {
        seed: 1001,
        leaves: vec![leaf],
        relays: Vec::new(),
        direct: vec![0],
        root_attaches: 1,
        depth: 64,
    };
    let rep = run_watched(&sc);
    check_conservation(&sc, &rep).unwrap();
    let gap = rep.leaf_stats[0].gaps;
    assert!(gap > 0, "a 3-event ring cannot cover a 20-event outage: {rep:?}");
    let origin = &rep.attaches[0].origins[0];
    assert_eq!(origin.resume_gaps, gap, "root ledger equals the leaf's own gap count");
    assert_eq!(origin.known_dropped(), gap, "the gap is booked exactly once");
    assert_eq!(rep.attaches[0].merged.len() as u64, n - gap);
    let rep2 = run_watched(&sc);
    check_determinism(&rep, &rep2)
        .unwrap_or_else(|e| panic!("the gap must reproduce exactly:\n{e}"));
}

/// Kill right at the Eos frame header, then refuse the redial three
/// times: with a roomy ring the fault costs reconnect attempts, never
/// events — the run still equals the golden.
#[test]
fn eos_frame_kill_with_refused_redials_recovers_to_golden() {
    let ts: Vec<u64> = (0..12).map(|i| 10 + i * 3).collect();
    let mut leaf = leaf_spec("flaky", 3, 0, &[&ts]);
    leaf.serve_faults = vec![FaultSpec { kill_at_frame: Some((T_EOS, 1)), ..Default::default() }];
    leaf.redial_refusals = vec![0, 3]; // the post-kill redial is refused 3×
    let sc = Scenario {
        seed: 1002,
        leaves: vec![leaf],
        relays: Vec::new(),
        direct: vec![0],
        root_attaches: 1,
        depth: 64,
    };
    let rep = run_watched(&sc);
    check_conservation(&sc, &rep).unwrap();
    assert_eq!(total_known_loss(&rep), 0, "roomy ring: the kill may cost a redial, never events");
    assert!(
        rep.attaches[0].stats.per[0].reconnects >= 1,
        "the killed session resumed: {:?}",
        rep.attaches[0].stats
    );
    assert_eq!(rep.attaches[0].merged, post_mortem_golden(&sc));
}

/// A 2-level tree with a colliding leaf hostname and mixed wire
/// versions: per-leaf ledgers stay disjoint by origin path, and the
/// tree merge equals the offline golden.
#[test]
fn relay_tree_with_mixed_wire_matches_golden() {
    let sc = Scenario {
        seed: 1003,
        leaves: vec![
            leaf_spec("nodeA", 2, 0, &[&[10, 14, 18, 22], &[12, 16]]),
            leaf_spec("nodeA", 3, 1, &[&[11, 15, 19]]), // colliding hostname
            leaf_spec("gamma", 3, 2, &[&[13, 17, 21, 25]]),
        ],
        relays: vec![RelaySpec {
            label: "relay1".to_string(),
            leaves: vec![0, 1],
            serve_faults: Vec::new(),
            redial_refusals: Vec::new(),
        }],
        direct: vec![2],
        root_attaches: 1,
        depth: 64,
    };
    let rep = run_watched(&sc);
    check_conservation(&sc, &rep).unwrap();
    assert_eq!(total_known_loss(&rep), 0);
    assert_eq!(rep.attaches[0].merged, post_mortem_golden(&sc));
    // the two nodeA leaves keep separate child ledgers under the relay
    let relay_origin = &rep.attaches[0].origins[0];
    assert_eq!(relay_origin.children.len(), 2, "{relay_origin:?}");
    assert_eq!(relay_origin.children[0].path, "0:nodeA");
    assert_eq!(relay_origin.children[1].path, "1:nodeA");
    assert_eq!(relay_origin.children[0].eos, Some((6, 0)));
    assert_eq!(relay_origin.children[1].eos, Some((3, 0)));
}

/// Two concurrent root attaches over one relayed session: both see the
/// identical merged stream, and it equals the golden.
#[test]
fn two_root_attaches_see_one_identical_session() {
    let sc = Scenario {
        seed: 1004,
        leaves: vec![
            leaf_spec("a", 3, 0, &[&[10, 13, 16, 19]]),
            leaf_spec("b", 2, 1, &[&[11, 14, 17, 20]]),
        ],
        relays: vec![RelaySpec {
            label: "relay1".to_string(),
            leaves: vec![0, 1],
            serve_faults: Vec::new(),
            redial_refusals: Vec::new(),
        }],
        direct: Vec::new(),
        root_attaches: 2,
        depth: 64,
    };
    let rep = run_watched(&sc);
    check_conservation(&sc, &rep).unwrap();
    assert_eq!(rep.attaches.len(), 2);
    assert_eq!(rep.attaches[0].merged, rep.attaches[1].merged);
    assert_eq!(rep.attaches[0].merged, post_mortem_golden(&sc));
}
