//! THRL wire-format conformance + decoder robustness.
//!
//! The conformance half pins the codec to **frozen golden bytes**
//! (`rust/tests/fixtures/thrl/*.hex`, one file per frame kind plus the
//! version-negotiation preamble): every fixture must decode to its
//! documented frame value and re-encode byte-identically. If the
//! encoding ever drifts from `docs/PROTOCOL.md` — field order, widths,
//! endianness, length accounting — these tests fail loudly instead of
//! letting two builds disagree on the wire. The fixtures are loaded
//! with `include_str!`, so deleting one fails the *build*, not just a
//! test run.
//!
//! The corpus freezes protocol **version 3** (the batched hot path:
//! EventBatch with delta timestamps, varint ids and the per-connection
//! key dictionary). v3 is a strict byte-superset of v2, and v2 stays a
//! *live* golden — `iprof serve --wire 2` must keep emitting exactly
//! the frozen v2 preamble and per-event frames — so both preambles are
//! asserted. The retired v1 fixtures (`preamble.hex`, `hello.hex`)
//! stay on disk as *rejection* goldens: a current build must refuse
//! them structurally, never mis-parse them.
//!
//! The robustness half is the hostile-input property: truncated,
//! bit-flipped and random byte streams must always produce a structured
//! [`FrameError`] (or a clean "incomplete") — never a panic, never an
//! unbounded allocation (length prefixes and stream counts are capped),
//! never misreading garbage as a frame that then over-consumes.

use thapi::remote::frame::{
    read_frame, read_preamble, write_preamble, write_preamble_version, MAX_FRAME_LEN, MAX_STREAMS,
};
use thapi::remote::{
    decode, decode_batch_into, decode_body, encode, BatchDict, BatchEvent, BatchKey, Frame,
    FrameError, WireEvent,
};
use thapi::tracer::encoder::FieldValue;
use thapi::util::prop;

/// Parse a `.hex` fixture: `#` lines are comments, whitespace is free.
fn unhex(fixture: &str) -> Vec<u8> {
    let hex: String = fixture
        .lines()
        .filter(|l| !l.trim_start().starts_with('#'))
        .collect::<Vec<_>>()
        .join("");
    let hex: String = hex.chars().filter(|c| !c.is_whitespace()).collect();
    assert_eq!(hex.len() % 2, 0, "odd hex digit count in fixture");
    (0..hex.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&hex[i..i + 2], 16).expect("bad hex in fixture"))
        .collect()
}

/// The frozen corpus: fixture name, raw file, and the frame value the
/// bytes MUST decode to (the same values documented in the fixture
/// comments and `docs/PROTOCOL.md`).
fn golden_frames() -> Vec<(&'static str, &'static str, Frame)> {
    vec![
        (
            "hello_v2",
            include_str!("fixtures/thrl/hello_v2.hex"),
            Frame::Hello {
                hostname: "node0".into(),
                metadata: "btf_version: 1\nevents:\n".into(),
                streams: 3,
                epoch: 0x0123_4567_89ab_cdef,
            },
        ),
        (
            "hello_v3",
            include_str!("fixtures/thrl/hello_v3.hex"),
            Frame::Hello {
                hostname: "node1".into(),
                metadata: "btf_version: 1\nevents:\n".into(),
                streams: 2,
                epoch: 0,
            },
        ),
        (
            "streams",
            include_str!("fixtures/thrl/streams.hex"),
            Frame::Streams { count: 7 },
        ),
        (
            "event",
            include_str!("fixtures/thrl/event.hex"),
            Frame::Event {
                stream: 2,
                event: WireEvent {
                    ts: u64::MAX,
                    rank: 1,
                    tid: 42,
                    class_id: 9,
                    fields: vec![
                        FieldValue::U64(7),
                        FieldValue::I64(-3),
                        FieldValue::F64(2.5),
                        FieldValue::Ptr(0xff00_0000_dead_beef),
                        FieldValue::Str("kernel".into()),
                    ],
                },
            },
        ),
        (
            "beacon",
            include_str!("fixtures/thrl/beacon.hex"),
            Frame::Beacon { stream: 0, watermark: 123_456 },
        ),
        (
            "drops",
            include_str!("fixtures/thrl/drops.hex"),
            Frame::Drops { stream: 5, dropped: 99 },
        ),
        (
            "close",
            include_str!("fixtures/thrl/close.hex"),
            Frame::Close { stream: 1 },
        ),
        (
            "eos",
            include_str!("fixtures/thrl/eos.hex"),
            Frame::Eos { received: 1000, dropped: 4 },
        ),
        (
            "resume",
            include_str!("fixtures/thrl/resume.hex"),
            Frame::Resume { epoch: 0x0123_4567_89ab_cdef, cursors: vec![7, 0, 42] },
        ),
        (
            "resume_gap",
            include_str!("fixtures/thrl/resume_gap.hex"),
            Frame::ResumeGap { stream: 2, missed: 17 },
        ),
        (
            "origin",
            include_str!("fixtures/thrl/origin.hex"),
            Frame::Origin {
                path: "0:nodeA".into(),
                hostname: "nodeA".into(),
                streams: vec![0, 1],
                dropped: 7,
                resume_gaps: 2,
                eos: Some((100, 7)),
            },
        ),
        (
            "event_batch",
            include_str!("fixtures/thrl/event_batch.hex"),
            Frame::EventBatch {
                stream: 2,
                events: vec![
                    BatchEvent {
                        ts: 1000,
                        key: BatchKey::Def { rank: 1, tid: 42, class_id: 9 },
                        fields: vec![FieldValue::U64(7)],
                    },
                    BatchEvent { ts: 999, key: BatchKey::Ref(0), fields: vec![] },
                    BatchEvent {
                        ts: 1007,
                        key: BatchKey::Ref(0),
                        fields: vec![FieldValue::Str("k".into())],
                    },
                ],
            },
        ),
    ]
}

// ---------------------------------------------------------------------------
// Conformance: frozen bytes <-> documented frames, both directions
// ---------------------------------------------------------------------------

#[test]
fn preamble_fixtures_are_frozen() {
    // the default preamble is v3 ...
    let golden_v3 = unhex(include_str!("fixtures/thrl/preamble_v3.hex"));
    let mut ours = Vec::new();
    write_preamble(&mut ours).unwrap();
    assert_eq!(
        ours, golden_v3,
        "preamble encoding drifted from the frozen fixture (docs/PROTOCOL.md)"
    );
    let v = read_preamble(&mut &golden_v3[..]).expect("the frozen v3 preamble must be accepted");
    assert_eq!(v, 3, "this corpus freezes protocol version 3");
    // ... and the v2 preamble stays a LIVE golden: `iprof serve --wire 2`
    // must keep producing exactly these bytes for old subscribers
    let golden_v2 = unhex(include_str!("fixtures/thrl/preamble_v2.hex"));
    let mut ours = Vec::new();
    write_preamble_version(&mut ours, 2).unwrap();
    assert_eq!(
        ours, golden_v2,
        "the --wire 2 fallback preamble drifted from the frozen v2 fixture"
    );
    let v = read_preamble(&mut &golden_v2[..]).expect("the frozen v2 preamble must be accepted");
    assert_eq!(v, 2, "v2 stays a supported fallback");
}

/// Version 2 deliberately broke v1 (the Hello layout grew a session
/// epoch): the retired v1 fixtures stay in the corpus as *rejection*
/// goldens — a v2 build must refuse them loudly rather than mis-parse.
#[test]
fn retired_v1_fixtures_are_rejected_not_misread() {
    // the v1 preamble fails version negotiation before any frame is read
    let v1 = unhex(include_str!("fixtures/thrl/preamble.hex"));
    let err = read_preamble(&mut &v1[..]).unwrap_err();
    assert!(err.to_string().contains("version 1"), "{err}");
    // and a v1 Hello body (no epoch) no longer decodes under v2 rules —
    // it is 8 bytes short, a structured Malformed error, never a guess
    let hello_v1 = unhex(include_str!("fixtures/thrl/hello.hex"));
    assert!(
        matches!(decode(&hello_v1), Err(FrameError::Malformed(_))),
        "a v1 Hello must fail structurally under v2"
    );
}

#[test]
fn every_fixture_decodes_to_its_golden_frame_and_reencodes_byte_identically() {
    for (name, raw, expected) in golden_frames() {
        let bytes = unhex(raw);
        let (frame, consumed) = decode(&bytes)
            .unwrap_or_else(|e| panic!("fixture {name} must decode: {e}"))
            .unwrap_or_else(|| panic!("fixture {name} is a complete frame"));
        assert_eq!(frame, expected, "fixture {name}: decoded frame drifted");
        assert_eq!(consumed, bytes.len(), "fixture {name}: length accounting drifted");
        let mut reencoded = Vec::new();
        encode(&expected, &mut reencoded);
        assert_eq!(
            reencoded, bytes,
            "fixture {name}: ENCODING drifted from the frozen wire bytes — \
             this breaks old subscribers; bump the protocol version instead"
        );
    }
}

#[test]
fn event_batch_fixture_decodes_identically_on_the_stateful_fast_path() {
    // decode_batch_into is what `iprof attach` actually runs; it must
    // agree byte-for-byte with the slow golden decode, resolving Refs
    // through the connection dictionary the Defs populate
    let bytes = unhex(include_str!("fixtures/thrl/event_batch.hex"));
    let body = &bytes[4..]; // strip the length prefix
    let mut dict = BatchDict::new();
    let mut seen: Vec<(u64, u32, u32, u32, usize)> = Vec::new();
    let (stream, n) = decode_batch_into(body, &mut dict, |ts, rank, tid, class_id, fields| {
        seen.push((ts, rank, tid, class_id, fields.len()));
    })
    .expect("the golden batch must decode on the fast path");
    assert_eq!((stream, n), (2, 3));
    assert_eq!(
        seen,
        vec![(1000, 1, 42, 9, 1), (999, 1, 42, 9, 0), (1007, 1, 42, 9, 1)],
        "fast-path decode drifted from the documented fixture values"
    );
}

#[test]
fn fixture_corpus_covers_every_frame_kind() {
    // one fixture per discriminant: adding a frame kind to the protocol
    // without freezing its bytes here must fail
    let frames = golden_frames();
    let kinds: std::collections::HashSet<std::mem::Discriminant<Frame>> =
        frames.iter().map(|(_, _, f)| std::mem::discriminant(f)).collect();
    assert_eq!(kinds.len(), 11, "fixture corpus no longer covers every frame kind");
}

#[test]
fn concatenated_fixtures_read_as_one_frame_stream() {
    // the whole corpus back to back after the preamble: the blocking
    // reader must consume it frame by frame with exact length accounting
    // (grammar-wise Resume flows the other way and EventBatch needs a v3
    // preamble, but the codec is direction- and version-agnostic)
    let mut wire = unhex(include_str!("fixtures/thrl/preamble_v3.hex"));
    let frames = golden_frames();
    for (_, raw, _) in &frames {
        wire.extend_from_slice(&unhex(raw));
    }
    let mut r = &wire[..];
    read_preamble(&mut r).unwrap();
    for (name, _, expected) in &frames {
        let got = read_frame(&mut r).unwrap_or_else(|e| panic!("reading {name}: {e}"));
        assert_eq!(&got, expected);
    }
    assert!(r.is_empty(), "nothing may trail the final fixture");
}

// ---------------------------------------------------------------------------
// Robustness: hostile inputs produce structured errors, never panics,
// never unbounded allocations
// ---------------------------------------------------------------------------

#[test]
fn hostile_length_prefixes_are_rejected_not_allocated() {
    // length prefix far beyond MAX_FRAME_LEN: structured error, and by
    // construction no allocation of the claimed size
    for len in [MAX_FRAME_LEN as u32 + 1, u32::MAX / 2, u32::MAX] {
        let mut buf = len.to_le_bytes().to_vec();
        buf.push(0x03);
        assert!(
            matches!(decode(&buf), Err(FrameError::BadLength(_))),
            "len {len} must be a BadLength error"
        );
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(err.to_string().contains("out of bounds"), "{err}");
    }
    // zero length is equally invalid (a frame always has a type byte)
    assert!(matches!(decode(&[0, 0, 0, 0]), Err(FrameError::BadLength(0))));
    // a maximal-but-legal length with missing bytes is "incomplete", so a
    // buffering reader waits instead of allocating eagerly
    let buf = (MAX_FRAME_LEN as u32).to_le_bytes().to_vec();
    assert_eq!(decode(&buf).unwrap(), None);
}

#[test]
fn hostile_field_and_string_counts_inside_bodies_are_structured_errors() {
    // an Event body claiming 65535 fields but carrying none: the decoder
    // must fail on the missing bytes, not pre-allocate 65535 entries
    let mut body = vec![0x03u8]; // T_EVENT
    body.extend_from_slice(&0u32.to_le_bytes()); // stream
    body.extend_from_slice(&0u64.to_le_bytes()); // ts
    body.extend_from_slice(&0u32.to_le_bytes()); // rank
    body.extend_from_slice(&0u32.to_le_bytes()); // tid
    body.extend_from_slice(&0u32.to_le_bytes()); // class
    body.extend_from_slice(&u16::MAX.to_le_bytes()); // nfields lie
    assert!(matches!(decode_body(&body), Err(FrameError::Malformed(_))));

    // a Hello whose str32 metadata length lies about the body size
    let mut body = vec![0x01u8]; // T_HELLO
    body.extend_from_slice(&0u16.to_le_bytes()); // empty hostname
    body.extend_from_slice(&u32::MAX.to_le_bytes()); // metadata length lie
    assert!(matches!(decode_body(&body), Err(FrameError::Malformed(_))));

    // a 7-byte EventBatch body claiming u64::MAX events: the varint count
    // is capped at MAX_BATCH_EVENTS before any table is allocated
    let mut body = vec![0x0au8]; // T_EVENT_BATCH
    body.extend_from_slice(&0u32.to_le_bytes()); // stream
    body.extend_from_slice(&[0xff; 10]); // varint u64::MAX event-count lie
    assert!(matches!(decode_body(&body), Err(FrameError::Malformed(_))));
    // and a batch referencing a dictionary slot that was never defined is
    // equally structural on the stateful fast path (key 2 = Ref(1) into
    // an empty connection dictionary)
    let mut body = vec![0x0au8];
    body.extend_from_slice(&0u32.to_le_bytes()); // stream
    body.push(0x01); // count = 1
    body.push(0x00); // ts delta 0
    body.push(0x02); // key = Ref(1): never defined
    body.push(0x00); // nfields = 0
    let mut dict = BatchDict::new();
    assert!(
        decode_batch_into(&body, &mut dict, |_, _, _, _, _| ()).is_err(),
        "dangling dictionary refs must not decode"
    );

    // MAX_STREAMS is the subscriber-side cap the reader enforces on
    // Streams/Event indices; sanity-pin its order of magnitude here so a
    // refactor can't silently turn it into an unbounded allocation
    assert!(MAX_STREAMS <= 1 << 20);
}

#[test]
fn prop_truncations_of_valid_wires_are_incomplete_or_structured_errors() {
    prop::check(100, 0xc0f0, |rng| {
        let frames = golden_frames();
        let (_, raw, _) = &frames[rng.range(0, frames.len())];
        let bytes = unhex(raw);
        // every strict prefix of a single valid frame reads as
        // "incomplete", never as a wrong frame and never as corruption
        let cut = rng.range(0, bytes.len());
        assert_eq!(decode(&bytes[..cut]).expect("prefix must not be an error"), None);
        // through the blocking reader a truncation is an UnexpectedEof
        // io error (the publisher died), still never a panic
        if cut > 0 {
            let _ = read_frame(&mut &bytes[..cut]);
        }
    });
}

#[test]
fn prop_bit_flips_never_panic_and_never_over_consume() {
    prop::check(300, 0xb17f, |rng| {
        // a small multi-frame wire, then one flipped bit anywhere
        let frames = golden_frames();
        let mut wire = Vec::new();
        for _ in 0..rng.range(1, 4) {
            let (_, raw, _) = &frames[rng.range(0, frames.len())];
            wire.extend_from_slice(&unhex(raw));
        }
        let bit = rng.range(0, wire.len() * 8);
        wire[bit / 8] ^= 1u8 << (bit % 8);
        // sequential decode must terminate with Ok(None), Ok(Some) with
        // sane consumption, or a structured error — anything but a panic
        // or runaway consumption
        let mut off = 0usize;
        let mut steps = 0usize;
        while off < wire.len() {
            match decode(&wire[off..]) {
                Ok(Some((_, n))) => {
                    assert!(n > 4 && n <= wire.len() - off, "consumed {n} of {}", wire.len() - off);
                    off += n;
                }
                Ok(None) => break,  // truncated tail: reader would wait
                Err(_) => break,    // structured protocol error: reader aborts
            }
            steps += 1;
            assert!(steps <= wire.len(), "decoder failed to make progress");
        }
    });
}

// ---------------------------------------------------------------------------
// Broadcast-order fuzz: one Broadcaster, two subscribers on different
// wires — whatever order the server's threads emit bytes in, each
// connection decodes independently (own buffer, own dictionary, own
// negotiated version), at EVERY possible interleave boundary
// ---------------------------------------------------------------------------

/// In-memory subscriber connection: the read side scripts exactly one
/// Resume (what a fresh subscriber sends after a resumable Hello), the
/// write side captures the publisher's bytes for offline fuzzing.
struct CapturedConn {
    input: std::io::Cursor<Vec<u8>>,
    out: std::sync::Arc<std::sync::Mutex<Vec<u8>>>,
}

impl std::io::Read for CapturedConn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.input.read(buf)
    }
}

impl std::io::Write for CapturedConn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.out.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// A per-connection incremental decoder, exactly what one subscriber
/// runs: buffers arbitrary chunks, negotiates its own preamble, keeps
/// its own batch dictionary, and accumulates decoded event timestamps.
#[derive(Default)]
struct SubDecoder {
    buf: Vec<u8>,
    version: Option<u32>,
    dict: BatchDict,
    events: Vec<u64>,
    batches: usize,
}

impl SubDecoder {
    fn feed(&mut self, bytes: &[u8]) {
        let SubDecoder { buf, version, dict, events, batches } = self;
        buf.extend_from_slice(bytes);
        let mut consumed = 0usize;
        if version.is_none() {
            if buf.len() < 8 {
                return;
            }
            let mut r = &buf[..8];
            *version = Some(read_preamble(&mut r).expect("preamble never corrupt mid-interleave"));
            consumed = 8;
        }
        loop {
            match decode(&buf[consumed..]) {
                Ok(Some((frame, n))) => {
                    match frame {
                        Frame::Event { event, .. } => events.push(event.ts),
                        Frame::EventBatch { .. } => {
                            // re-decode through THIS connection's
                            // dictionary (the stateful fast path)
                            *batches += 1;
                            let body = &buf[consumed + 4..consumed + n];
                            decode_batch_into(body, dict, |ts, _, _, _, _| events.push(ts))
                                .expect("batch refs resolve through the connection dictionary");
                        }
                        _ => {}
                    }
                    consumed += n;
                }
                Ok(None) => break,
                Err(e) => panic!("structured decode error mid-interleave: {e}"),
            }
        }
        buf.drain(..consumed);
    }
}

#[test]
fn broadcast_byte_interleave_decodes_per_connection_at_every_boundary() {
    use thapi::live::LiveHub;
    use thapi::remote::Broadcaster;
    const EPOCH: u64 = 0xF022;

    let reg_msg = |hub: &LiveHub, j: usize, ts: u64| {
        let name =
            if j % 2 == 0 { "lttng_ust_ze:zeInit_entry" } else { "lttng_ust_ze:zeInit_exit" };
        let class = thapi::model::class_by_name(name).unwrap();
        hub.decode(0, 1, class.id, ts, &0u64.to_le_bytes()).unwrap()
    };
    let ts_of = |i: u64| 10 + i * 5;
    let hub = LiveHub::new("fuzzhost", 64, false);
    hub.ensure_channels(1);
    hub.push_batch(0, (0..4).map(|i| reg_msg(&hub, i as usize, ts_of(i))).collect());

    let bc = Broadcaster::new(hub.clone(), EPOCH, 64 << 20);
    bc.drain_to_ring();
    let scripted = || {
        let mut resume = Vec::new();
        encode(&Frame::Resume { epoch: EPOCH, cursors: vec![] }, &mut resume);
        let out = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        (CapturedConn { input: std::io::Cursor::new(resume), out: out.clone() }, out)
    };

    // subscriber A (v3) is served LIVE across two rounds, so its wire
    // carries both per-event replay and batched frames; subscriber B
    // (v2) attaches after the end — pure per-event replay
    let (conn_a, out_a) = scripted();
    let (conn_b, out_b) = scripted();
    std::thread::scope(|s| {
        let bc = &bc;
        let a = s.spawn(move || bc.serve_connection(conn_a, 3));
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        while bc.subscriber_stats().first().map(|r| r.forwarded) != Some(4) {
            assert!(std::time::Instant::now() < deadline, "subscriber A never got the replay");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        hub.push_batch(0, (4..8).map(|i| reg_msg(&hub, i as usize, ts_of(i))).collect());
        hub.close_all();
        bc.pump();
        a.join().unwrap();
        s.spawn(move || bc.serve_connection(conn_b, 2)).join().unwrap();
    });
    let wire_a = out_a.lock().unwrap().clone();
    let wire_b = out_b.lock().unwrap().clone();
    let expected: Vec<u64> = (0..8).map(ts_of).collect();

    // uninterleaved baselines — and the negotiation is per-connection:
    // A's wire really batches, B's never does
    let (mut base_a, mut base_b) = (SubDecoder::default(), SubDecoder::default());
    base_a.feed(&wire_a);
    base_b.feed(&wire_b);
    assert_eq!((base_a.version, base_b.version), (Some(3), Some(2)));
    assert_eq!(base_a.events, expected);
    assert_eq!(base_b.events, expected);
    assert!(base_a.batches >= 1, "the live v3 rounds must batch");
    assert_eq!(base_b.batches, 0, "v2 must never see EventBatch");

    // every byte boundary of A's stream, with ALL of B delivered in
    // between: per-connection decoding must be oblivious to the
    // server-side emission order — broadcast is invisible on the wire
    for cut in 0..=wire_a.len() {
        let (mut da, mut db) = (SubDecoder::default(), SubDecoder::default());
        da.feed(&wire_a[..cut]);
        db.feed(&wire_b);
        da.feed(&wire_a[cut..]);
        assert_eq!(da.version, Some(3), "cut {cut}: negotiation stays per-connection");
        assert_eq!(db.version, Some(2), "cut {cut}");
        assert_eq!(da.events, expected, "cut {cut}: A's decode must not depend on order");
        assert_eq!(db.events, expected, "cut {cut}");
    }
}

// ---------------------------------------------------------------------------
// Stateful v3 session fuzz: a session whose continuation is decodable
// ONLY through the dictionary its opening built. Truncating or
// corrupting it anywhere must stay structural — "incomplete" or a
// FrameError — never a panic and never a stale decode.
// ---------------------------------------------------------------------------

/// A frozen two-part v3 session. `prime` opens it: preamble, Hello,
/// and an EventBatch whose `Def` keys populate the connection
/// dictionary. `cont` continues it with Ref-only batches (slots 0 and
/// 1), a Drops, and the Eos — bytes that only make sense against the
/// state `prime` established.
fn primed_session() -> (Vec<u8>, Vec<u8>) {
    let mut prime = Vec::new();
    write_preamble_version(&mut prime, 3).unwrap();
    encode(
        &Frame::Hello {
            hostname: "fuzzhost".into(),
            metadata: "btf_version: 1\nevents:\n".into(),
            streams: 2,
            epoch: 0xF422,
        },
        &mut prime,
    );
    encode(
        &Frame::EventBatch {
            stream: 0,
            events: vec![
                BatchEvent {
                    ts: 1_000,
                    key: BatchKey::Def { rank: 0, tid: 7, class_id: 9 },
                    fields: vec![FieldValue::U64(1)],
                },
                BatchEvent {
                    ts: 1_010,
                    key: BatchKey::Def { rank: 0, tid: 8, class_id: 9 },
                    fields: vec![],
                },
            ],
        },
        &mut prime,
    );
    let mut cont = Vec::new();
    encode(
        &Frame::EventBatch {
            stream: 0,
            events: vec![
                BatchEvent { ts: 1_020, key: BatchKey::Ref(0), fields: vec![FieldValue::U64(2)] },
                BatchEvent { ts: 1_025, key: BatchKey::Ref(1), fields: vec![] },
                BatchEvent {
                    ts: 1_040,
                    key: BatchKey::Ref(0),
                    fields: vec![FieldValue::Str("k".into())],
                },
            ],
        },
        &mut cont,
    );
    encode(
        &Frame::EventBatch {
            stream: 1,
            events: vec![BatchEvent { ts: 1_050, key: BatchKey::Ref(1), fields: vec![] }],
        },
        &mut cont,
    );
    encode(&Frame::Drops { stream: 1, dropped: 2 }, &mut cont);
    encode(&Frame::Eos { received: 6, dropped: 2 }, &mut cont);
    (prime, cont)
}

/// Drive one fresh stateful session over `bytes`: negotiate the
/// preamble, decode frames in order, resolve every batch through the
/// session's own dictionary. `Ok((events, complete))` is a clean
/// outcome (`complete` = an Eos was reached); `Err` is the structured
/// error that stopped the session. Anything else — a panic — fails the
/// calling test.
fn run_session(bytes: &[u8]) -> Result<(Vec<u64>, bool), String> {
    if bytes.len() < 8 {
        return Ok((Vec::new(), false));
    }
    let mut r = &bytes[..];
    read_preamble(&mut r).map_err(|e| e.to_string())?;
    let buf = r;
    let mut dict = BatchDict::new();
    let mut events = Vec::new();
    let mut off = 0usize;
    while off < buf.len() {
        match decode(&buf[off..]) {
            Ok(Some((frame, n))) => {
                match frame {
                    Frame::Event { event, .. } => events.push(event.ts),
                    Frame::EventBatch { .. } => {
                        let body = &buf[off + 4..off + n];
                        decode_batch_into(body, &mut dict, |ts, _, _, _, _| events.push(ts))
                            .map_err(|e| e.to_string())?;
                    }
                    Frame::Eos { .. } => return Ok((events, true)),
                    _ => {}
                }
                off += n;
            }
            Ok(None) => break,
            Err(e) => return Err(e.to_string()),
        }
    }
    Ok((events, false))
}

#[test]
fn stateful_v3_session_truncations_are_incomplete_or_structured() {
    let (prime, cont) = primed_session();
    // the full session decodes to the documented timeline, Refs
    // resolving through the dictionary the prime built
    let full: Vec<u8> = [prime.clone(), cont.clone()].concat();
    let (events, complete) = run_session(&full).expect("the frozen session must decode");
    assert!(complete, "the session ends in Eos");
    assert_eq!(events, vec![1_000, 1_010, 1_020, 1_025, 1_040, 1_050]);
    // every strict prefix of the continuation, each against a FRESH
    // session primed with the same opening bytes: always "incomplete",
    // never an error, and whatever decoded is a prefix of the full
    // timeline — a half-delivered batch contributes nothing
    for cut in 0..cont.len() {
        let mut wire = prime.clone();
        wire.extend_from_slice(&cont[..cut]);
        let (seen, complete) =
            run_session(&wire).unwrap_or_else(|e| panic!("cut {cut}: structured error: {e}"));
        assert!(!complete, "cut {cut}: Eos cannot appear before the final byte");
        assert_eq!(
            seen,
            events[..seen.len()],
            "cut {cut}: a truncated session must decode a prefix, never invented events"
        );
    }
    // and WITHOUT the prime the continuation is structurally dead: its
    // Refs point into a dictionary that was never populated
    let mut bare = Vec::new();
    write_preamble_version(&mut bare, 3).unwrap();
    bare.extend_from_slice(&cont);
    assert!(
        run_session(&bare).is_err(),
        "dangling dictionary Refs must not decode in a fresh session"
    );
}

#[test]
fn prop_stateful_v3_session_bit_flips_fail_structurally_never_panic() {
    let (prime, cont) = primed_session();
    let full: Vec<u8> = [prime, cont].concat();
    prop::check(400, 0xd1c7, |rng| {
        let mut wire = full.clone();
        let bit = rng.range(0, wire.len() * 8);
        wire[bit / 8] ^= 1u8 << (bit % 8);
        // any structured outcome is acceptable — a clean decode (the
        // flip landed in payload bytes), "incomplete" (a length prefix
        // grew), or an error (a Ref, count or length went dangling) —
        // but never a panic and never a runaway timeline
        if let Ok((events, _)) = run_session(&wire) {
            // the whole wire is ~200 bytes and a decoded event costs
            // >= 3 of them: anything past this bound decoded bytes
            // that do not exist
            assert!(events.len() <= 256, "bit {bit}: runaway decode of a corrupt session");
        }
    });
}

#[test]
fn prop_random_byte_streams_never_panic_the_decoder() {
    prop::check(500, 0x5eed, |rng| {
        let n = rng.range(0, 128);
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        // plain decode: any structured outcome is fine
        match decode(&bytes) {
            Ok(Some((_, consumed))) => assert!(consumed <= bytes.len()),
            Ok(None) | Err(_) => {}
        }
        // body decode at every offset: same bar
        if !bytes.is_empty() {
            let off = rng.range(0, bytes.len());
            let _ = decode_body(&bytes[off..]);
        }
        // and through the blocking reader
        let _ = read_frame(&mut &bytes[..]);
    });
}
