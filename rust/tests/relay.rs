//! Hierarchical relay fan-in tests (`iprof relay <listen> <addr>...`).
//!
//! The acceptance bar: a 2-level collection tree — N leaf publishers,
//! two relays aggregating them, one root attach over the relays —
//! merges **byte-identically** to a flat N-way attach straight at the
//! leaves, with per-leaf accounting intact at the root. Identity
//! travels as [`Frame::Origin`] entries with path-style hierarchical
//! origin ids, so two relays each forwarding a leaf named `nodeA`
//! can never collapse into one ledger or telemetry series (the
//! origin-aliasing bug this suite pins). A resume gap booked at a
//! relay's downstream hop survives aggregation: the root's per-leaf
//! gap ledger equals the leaf publisher's own count, and a killed
//! root↔relay connection resumes byte-identically with the ledgers
//! re-learned.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;
use thapi::analysis::EventMsg;
use thapi::coordinator::{run_relay, RelayReport};
use thapi::live::{LiveHub, OriginStats};
use thapi::remote::{
    encode, FanIn, FanInStats, Frame, KillAfter, PublishStats, Publisher, ReconnectPolicy,
    ServeOutcome, WireEvent,
};
use thapi::tracer::btf::generate_metadata;
use thapi::tracer::encoder::FieldValue;

/// Decode a registry-class message through `hub` (so the class id
/// resolves on the attach side exactly like a real consumer's would).
fn reg_msg(hub: &LiveHub, name: &str, ts: u64, rank: u32, tid: u32) -> EventMsg {
    let class = thapi::model::class_by_name(name).unwrap();
    hub.decode(rank, tid, class.id, ts, &0u64.to_le_bytes()).unwrap()
}

/// A sealed leaf hub: one channel per batch, entry/exit alternating.
fn leaf_hub(hostname: &str, batches: &[Vec<(u64, u32)>]) -> Arc<LiveHub> {
    let hub = LiveHub::new(hostname, 64, false);
    hub.ensure_channels(batches.len());
    for (i, b) in batches.iter().enumerate() {
        let msgs = b
            .iter()
            .enumerate()
            .map(|(j, &(ts, tid))| {
                let name = if j % 2 == 0 {
                    "lttng_ust_ze:zeInit_entry"
                } else {
                    "lttng_ust_ze:zeInit_exit"
                };
                reg_msg(&hub, name, ts, 0, tid)
            })
            .collect();
        hub.push_batch(i, msgs);
    }
    hub.close_all();
    hub
}

/// Serve one resumable leaf session over TCP until the wire reaches
/// Eos; `kill_after[k]` kills the `k`-th accepted connection after
/// that many written bytes (fault injection — connections beyond the
/// schedule run clean) and keeps accepting for the resume.
fn serve_resumable_publisher(
    listener: TcpListener,
    hub: Arc<LiveHub>,
    epoch: u64,
    resume_buffer: usize,
    kill_after: Vec<usize>,
) -> PublishStats {
    let mut publisher = Publisher::new(hub, epoch, resume_buffer);
    let mut conn_idx = 0usize;
    loop {
        let (conn, _) = listener.accept().unwrap();
        let budget = kill_after.get(conn_idx).copied().unwrap_or(usize::MAX);
        conn_idx += 1;
        let conn = KillAfter::new(conn, budget);
        match publisher.serve_connection(conn) {
            ServeOutcome::Complete => return publisher.stats(),
            ServeOutcome::Lost(_) => continue,
        }
    }
}

/// Bind + serve every leaf on its own thread; returns their addresses
/// in leaf order (which fixes origin order everywhere downstream).
fn start_leaves<'scope>(
    s: &'scope std::thread::Scope<'scope, '_>,
    leaves: &[(&str, Vec<Vec<(u64, u32)>>)],
) -> Vec<std::net::SocketAddr> {
    leaves
        .iter()
        .map(|(host, batches)| {
            let hub = leaf_hub(host, batches);
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            s.spawn(move || serve_resumable_publisher(listener, hub, 0x1EAF, 1 << 20, Vec::new()));
            addr
        })
        .collect()
}

/// One relay node over real sockets: fan-in from `downstream`, one
/// broadcast listener upstream — what `iprof relay` runs. Optionally
/// kill the FIRST upstream connection after a written-byte budget.
fn run_relay_node(
    label: &str,
    listener: TcpListener,
    downstream: Vec<std::net::SocketAddr>,
    subscribers: usize,
    kill_first_after: Option<usize>,
) -> std::io::Result<RelayReport> {
    listener.set_nonblocking(true).unwrap();
    let mut kill = kill_first_after;
    let accept = move || -> std::io::Result<Option<KillAfter<TcpStream>>> {
        match listener.accept() {
            Ok((conn, _)) => {
                conn.set_nonblocking(false)?;
                Ok(Some(KillAfter::new(conn, kill.take().unwrap_or(usize::MAX))))
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
                Ok(None)
            }
            Err(e) => Err(e),
        }
    };
    let connectors: Vec<_> = downstream
        .into_iter()
        .map(|addr| move || TcpStream::connect(addr))
        .collect();
    run_relay(
        connectors,
        64,
        ReconnectPolicy { attempts: 8, backoff: Duration::from_millis(10) },
        Some(label),
        accept,
        subscribers,
        1 << 20,
        None,
        &Default::default(),
    )
}

/// Attach to `addrs`, drain the merged union, and report the tuple
/// stream (leaf hostnames included — the byte-identity payload) plus
/// the root hub's per-origin accounting.
#[allow(clippy::type_complexity)]
fn attach_all(
    addrs: &[std::net::SocketAddr],
) -> (Vec<(u64, u32, u32, String)>, Vec<OriginStats>, FanInStats) {
    let mk = |addr: std::net::SocketAddr| move || TcpStream::connect(addr);
    let fan = FanIn::open_resumable(
        addrs.iter().map(|&a| mk(a)).collect::<Vec<_>>(),
        64,
        ReconnectPolicy { attempts: 8, backoff: Duration::from_millis(10) },
    )
    .unwrap();
    let merged: Vec<(u64, u32, u32, String)> = fan
        .source()
        .map(|m| (m.ts, m.rank, m.tid, m.hostname.to_string()))
        .collect();
    let origins = fan.hub().origin_stats();
    let stats = fan.finish().unwrap();
    (merged, origins, stats)
}

/// Wire size of the Hello a publisher sends — the epoch and stream
/// count are fixed-width, so only the hostname length matters; lets a
/// test aim its kill budget past the handshake into the event stream.
fn hello_wire_len(hostname: &str) -> usize {
    let mut buf = Vec::new();
    encode(
        &Frame::Hello {
            hostname: hostname.into(),
            metadata: generate_metadata(&[]),
            streams: 0,
            epoch: 0,
        },
        &mut buf,
    );
    buf.len()
}

/// Wire size of one per-event v2 `Event` frame for our registry
/// payloads — sizes leaf replay rings in whole events.
fn event_len() -> usize {
    let mut buf = Vec::new();
    encode(
        &Frame::Event {
            stream: 0,
            event: WireEvent {
                ts: 10,
                rank: 0,
                tid: 1,
                class_id: thapi::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap().id,
                fields: vec![FieldValue::U64(0)],
            },
        },
        &mut buf,
    );
    buf.len()
}

// ---------------------------------------------------------------------------
// Golden: the 2-level tree vs the flat N-way attach, byte for byte —
// with two leaves deliberately SHARING a hostname across relays, so any
// origin aliasing under re-aggregation would corrupt the comparison
// ---------------------------------------------------------------------------

#[test]
fn two_level_tree_merges_byte_identically_to_flat_attach() {
    // cross-leaf timestamp ties force the merge tie-break; "nodeA"
    // appears under BOTH relays (each relay's origin 0), so the paths
    // arriving at the root collide textually ("0:nodeA") and only the
    // parent-origin namespacing keeps their ledgers apart
    let leaves: Vec<(&str, Vec<Vec<(u64, u32)>>)> = vec![
        ("nodeA", vec![vec![(10, 1), (15, 1), (20, 1), (25, 1)], vec![(12, 2), (17, 2)]]),
        ("leafB", vec![vec![(10, 3), (16, 3), (21, 3)]]),
        ("nodeA", vec![vec![(11, 4), (15, 4), (22, 4), (30, 4)]]),
        ("leafD", vec![vec![(10, 5), (25, 5)], vec![(13, 6)]]),
    ];
    let total: usize = leaves.iter().map(|(_, b)| b.iter().map(Vec::len).sum::<usize>()).sum();

    // flat reference: one 4-way attach straight at the leaves
    let (flat, flat_origins, flat_stats) = std::thread::scope(|s| {
        let addrs = start_leaves(s, &leaves);
        attach_all(&addrs)
    });
    assert_eq!(flat.len(), total);
    assert_eq!(flat_stats.failed(), 0);
    assert_eq!(flat_origins.len(), 4);
    assert!(
        flat.iter().all(|(_, _, _, h)| h == "nodeA" || h == "leafB" || h == "leafD"),
        "the reference stamps leaf hostnames"
    );

    // tree: leaves 0,1 -> relay1; leaves 2,3 -> relay2; root attaches
    // to the two relays only
    let (tree, origins, stats, rep1, rep2) = std::thread::scope(|s| {
        let addrs = start_leaves(s, &leaves);
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l2 = TcpListener::bind("127.0.0.1:0").unwrap();
        let (r1, r2) = (l1.local_addr().unwrap(), l2.local_addr().unwrap());
        let (down1, down2) = (vec![addrs[0], addrs[1]], vec![addrs[2], addrs[3]]);
        let h1 = s.spawn(move || run_relay_node("relay1", l1, down1, 1, None));
        let h2 = s.spawn(move || run_relay_node("relay2", l2, down2, 1, None));
        let (tree, origins, stats) = attach_all(&[r1, r2]);
        let rep1 = h1.join().unwrap().unwrap();
        let rep2 = h2.join().unwrap().unwrap();
        (tree, origins, stats, rep1, rep2)
    });

    assert_eq!(stats.failed(), 0);
    assert_eq!(
        tree, flat,
        "a 2-level tree must merge byte-identically to the flat N-way attach"
    );

    // per-leaf accounting survives aggregation, namespaced per relay
    assert_eq!(origins.len(), 2, "the root sees two direct origins: the relays");
    assert_eq!((origins[0].label.as_str(), origins[1].label.as_str()), ("relay1", "relay2"));
    assert_eq!(origins[0].children.len(), 2, "{:?}", origins[0].children);
    assert_eq!(origins[1].children.len(), 2, "{:?}", origins[1].children);
    let (a1, b1) = (&origins[0].children[0], &origins[0].children[1]);
    let (a2, d2) = (&origins[1].children[0], &origins[1].children[1]);
    assert_eq!((a1.path.as_str(), a1.hostname.as_str()), ("0:nodeA", "nodeA"));
    assert_eq!((b1.path.as_str(), b1.hostname.as_str()), ("1:leafB", "leafB"));
    assert_eq!((a2.path.as_str(), a2.hostname.as_str()), ("0:nodeA", "nodeA"));
    assert_eq!((d2.path.as_str(), d2.hostname.as_str()), ("1:leafD", "leafD"));
    // the colliding "0:nodeA" paths stayed SEPARATE ledgers because
    // they live under different parent origins — the aliasing pin
    assert_eq!(a1.eos, Some((6, 0)), "leaf Eos totals survive two hops");
    assert_eq!(a2.eos, Some((4, 0)), "…and do not alias across relays");
    assert_eq!(b1.eos, Some((3, 0)));
    assert_eq!(d2.eos, Some((3, 0)));
    assert_eq!((origins[0].received, origins[1].received), (9, 7));
    assert!(origins.iter().all(|o| o.known_dropped() == 0), "{origins:?}");

    // each relay's own report agrees with what the root booked
    assert_eq!(rep1.label, "relay1");
    assert_eq!(rep1.hostnames, vec!["nodeA".to_string(), "leafB".to_string()]);
    assert_eq!(rep1.downstream.failed(), 0);
    assert_eq!((rep1.local.received, rep1.publish.events), (9, 9));
    assert_eq!(rep2.label, "relay2");
    assert_eq!(rep2.downstream.failed(), 0);
    assert_eq!((rep2.local.received, rep2.publish.events), (7, 7));
}

// ---------------------------------------------------------------------------
// Ledger propagation: a resume gap booked on a relay's DOWNSTREAM hop
// arrives at the root as that leaf's child ledger, exactly — the root's
// per-origin gap ledgers match the leaf publishers' own counts
// ---------------------------------------------------------------------------

#[test]
fn leaf_resume_gap_survives_aggregation_to_the_root_ledger() {
    // lossy leaf: 40 events, a replay ring of ~3 event frames, and the
    // first connection killed 20 events in — the relay's resume MUST
    // come back with a gap; healthy leaf: 4 clean events
    let n_events = 40u64;
    let ev = event_len();
    let kill_at = 8 + hello_wire_len("lossy") + 20 * ev;

    let lossy = leaf_hub(
        "lossy",
        &[(0..n_events).map(|i| (10 + i * 5, 1u32)).collect::<Vec<_>>()],
    );
    let healthy_batches = vec![vec![(11u64, 9u32), (16, 9), (21, 9), (26, 9)]];

    let listener_lossy = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr_lossy = listener_lossy.local_addr().unwrap();

    let (origins, stats, rep, leaf_stats) = std::thread::scope(|s| {
        let leaf = s.spawn(move || {
            serve_resumable_publisher(listener_lossy, lossy, 0x10557, 3 * ev, vec![kill_at])
        });
        let addr_healthy = start_leaves(s, &[("healthy", healthy_batches.clone())])[0];
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let r1 = l1.local_addr().unwrap();
        let relay = s.spawn(move || {
            run_relay_node("relay1", l1, vec![addr_lossy, addr_healthy], 1, None)
        });
        let (merged, origins, stats) = attach_all(&[r1]);
        let rep = relay.join().unwrap().unwrap();
        let leaf_stats = leaf.join().unwrap();
        // everything outside the gap was merged exactly once at the root
        let gap = rep.origins[0].resume_gaps;
        assert_eq!(merged.len() as u64, n_events - gap + 4);
        (origins, stats, rep, leaf_stats)
    });

    assert_eq!(stats.failed(), 0, "nobody died: the gap is accounted, not fatal");
    // the relay saw the gap on its own downstream hop...
    let gap = rep.origins[0].resume_gaps;
    assert!(gap > 0, "a 3-event ring cannot cover a 20-event outage: {rep:?}");
    assert_eq!(leaf_stats.gaps, gap, "relay and leaf publisher agree on the exact loss");
    assert_eq!(rep.downstream.failed(), 0, "the relay resumed, its fan-in stayed whole");

    // ...and the root books the SAME count against the leaf's child
    // ledger, not against the relay or the healthy sibling
    assert_eq!(origins.len(), 1);
    assert_eq!(origins[0].resume_gaps, 0, "the root↔relay hop itself was lossless");
    let (lossy_kid, healthy_kid) = (&origins[0].children[0], &origins[0].children[1]);
    assert_eq!(lossy_kid.path, "0:lossy");
    assert_eq!(lossy_kid.resume_gaps, gap, "the leaf's gap ledger survives aggregation");
    assert_eq!(healthy_kid.path, "1:healthy");
    assert_eq!(healthy_kid.resume_gaps, 0);
    assert_eq!(healthy_kid.eos, Some((4, 0)));
    assert_eq!(
        origins[0].known_dropped(),
        gap,
        "root known loss = Σ leaf ledgers, nothing double-counted"
    );
}

// ---------------------------------------------------------------------------
// Repeated kill-resume on the SAME leaf: a gap is booked once per
// incident, never once per reconnect — killing the resumed connection
// too (which re-replays the unchanged ring) must leave the ledgers
// identical to the single-kill run, and the sibling's ledger untouched
// ---------------------------------------------------------------------------

#[test]
fn repeated_leaf_kill_resume_books_each_gap_once_and_keeps_ledgers_disjoint() {
    let n_events = 40u64;
    let ev = event_len();
    // kill 1 lands 20 events into the first connection (past the ring);
    // kill 2 lands just past the resumed connection's handshake, while
    // it is re-replaying the ring — which has NOT moved in between
    let kill1 = 8 + hello_wire_len("lossy") + 20 * ev;
    let kill2 = 8 + hello_wire_len("lossy") + 10;
    let batches: Vec<Vec<(u64, u32)>> =
        vec![(0..n_events).map(|i| (10 + i * 5, 1u32)).collect()];
    let healthy_batches = vec![vec![(11u64, 9u32), (16, 9), (21, 9), (26, 9)]];

    let run = |kills: Vec<usize>| {
        let lossy = leaf_hub("lossy", &batches);
        let listener_lossy = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr_lossy = listener_lossy.local_addr().unwrap();
        std::thread::scope(|s| {
            let leaf = s.spawn(move || {
                serve_resumable_publisher(listener_lossy, lossy, 0x10557, 3 * ev, kills)
            });
            let addr_healthy = start_leaves(s, &[("healthy", healthy_batches.clone())])[0];
            let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
            let r1 = l1.local_addr().unwrap();
            let relay = s.spawn(move || {
                run_relay_node("relay1", l1, vec![addr_lossy, addr_healthy], 1, None)
            });
            let (merged, origins, stats) = attach_all(&[r1]);
            let rep = relay.join().unwrap().unwrap();
            let leaf_stats = leaf.join().unwrap();
            assert_eq!(stats.failed(), 0, "every outage resumed: {stats:?}");
            (merged, origins, rep, leaf_stats)
        })
    };

    let (m1, o1, rep1, ls1) = run(vec![kill1]);
    let (m2, o2, rep2, ls2) = run(vec![kill1, kill2]);

    assert!(ls1.gaps > 0, "the first outage must cost events: {ls1:?}");
    assert_eq!(ls1.connections, 2, "{ls1:?}");
    assert_eq!(rep1.origins[0].resume_gaps, ls1.gaps);
    assert_eq!(o1[0].children[0].resume_gaps, ls1.gaps);

    // the second kill really happened (one more accepted connection)…
    assert_eq!(ls2.connections, 3, "two kills → three connections: {ls2:?}");
    // …but re-replaying the unchanged ring books NO new gap, anywhere
    assert_eq!(ls2.gaps, ls1.gaps, "a re-replayed incident must not re-book its gap");
    assert_eq!(rep2.origins[0].resume_gaps, ls1.gaps, "relay ledger: once per incident");
    assert_eq!(m2, m1, "the merged stream is outage-count-independent");

    // per-leaf child ledgers at the root stay exact and disjoint
    let (lossy_kid, healthy_kid) = (&o2[0].children[0], &o2[0].children[1]);
    assert_eq!(lossy_kid.path, "0:lossy");
    assert_eq!(lossy_kid.resume_gaps, ls1.gaps);
    assert_eq!(lossy_kid.eos, Some((n_events, 0)));
    assert_eq!(healthy_kid.path, "1:healthy");
    assert_eq!(healthy_kid.resume_gaps, 0, "the sibling's ledger is untouched");
    assert_eq!(healthy_kid.eos, Some((4, 0)));
    assert_eq!(o2[0].known_dropped(), ls1.gaps, "booked exactly once across the tree");
    assert_eq!(m2.len() as u64, n_events - ls1.gaps + 4);
}

// ---------------------------------------------------------------------------
// Resume golden: killing the root↔relay connection mid-stream and
// resuming is byte-identical to the flat attach — the fresh slot
// re-receives every Origin entry, so stamping and ledgers re-learn
// ---------------------------------------------------------------------------

#[test]
fn killed_relay_upstream_connection_resumes_byte_identically() {
    let leaves: Vec<(&str, Vec<Vec<(u64, u32)>>)> = vec![
        (
            "leafA",
            vec![
                (0u64..120).map(|i| (10 + i * 3, 1u32)).collect::<Vec<_>>(),
                vec![(12, 2), (500, 2)],
            ],
        ),
        ("leafB", vec![(0u64..80).map(|i| (11 + i * 4, 9u32)).collect::<Vec<_>>()]),
    ];
    let total: usize = leaves.iter().map(|(_, b)| b.iter().map(Vec::len).sum::<usize>()).sum();

    let (flat, _, flat_stats) = std::thread::scope(|s| {
        let addrs = start_leaves(s, &leaves);
        attach_all(&addrs)
    });
    assert_eq!(flat_stats.failed(), 0);
    assert_eq!(flat.len(), total);

    // the cut lands past the relay's handshake, inside the event stream
    // (possibly mid-frame) — exactly what the resume must absorb
    let kill_at = 8 + hello_wire_len("relay1") + 600;
    let (tree, origins, stats, rep) = std::thread::scope(|s| {
        let addrs = start_leaves(s, &leaves);
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let r1 = l1.local_addr().unwrap();
        let relay =
            s.spawn(move || run_relay_node("relay1", l1, addrs, 1, Some(kill_at)));
        let (tree, origins, stats) = attach_all(&[r1]);
        (tree, origins, stats, relay.join().unwrap().unwrap())
    });

    assert_eq!(stats.failed(), 0, "the root resumed, nobody died: {stats:?}");
    assert!(stats.per[0].reconnects >= 1, "the upstream hop was killed and re-joined: {stats:?}");
    assert_eq!(
        tree, flat,
        "a killed-and-resumed relay hop must merge byte-identically to the flat attach"
    );
    // a roomy relay ring replays everything: no gap anywhere, and the
    // re-sent Origin entries rebuilt the full child ledger set
    assert_eq!(origins[0].resume_gaps, 0);
    assert_eq!(origins[0].children.len(), 2, "{:?}", origins[0].children);
    assert_eq!(origins[0].children[0].eos, Some((122, 0)));
    assert_eq!(origins[0].children[1].eos, Some((80, 0)));
    assert_eq!(origins[0].known_dropped(), 0);
    assert_eq!(rep.disconnects.len(), 1, "the relay logged the killed connection: {rep:?}");
}
