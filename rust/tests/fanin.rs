//! Multi-publisher fan-in tests (`iprof attach <addr> <addr>...`).
//!
//! The acceptance bar: attaching to N **lossless** publishers is
//! byte-identical to a single local `--live` run over the concatenated
//! stream set — pinned by a split-trace TCP golden and a randomized
//! merge-order property — and a publisher that dies mid-stream degrades
//! the union to a partial-but-correct analysis with exact per-publisher
//! drop/EOS accounting, never a torn-down session. Stream-id collisions
//! across publishers (the latent `LiveHub` aliasing bug the fan-in
//! design surfaced) are pinned too.

use std::io::Cursor;
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;
use thapi::analysis::{
    self, AnalysisSink, EventMsg, MessageSource, ParsedTrace, TallySink, TimelineSink,
};
use thapi::coordinator::{run, run_fanin, run_fanin_resumable, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::live::{replay_trace, run_live_pipeline, LiveHub, LiveSource};
use thapi::remote::{
    frame, publish, publish_with, FanIn, Frame, KillAfter, PublishStats, Publisher,
    ReconnectPolicy, ServeOutcome, WireEvent,
};
use thapi::tracer::btf::{generate_metadata, DecodedClass, Metadata, TraceData};
use thapi::util::prop;

/// Global-session tests cannot overlap.
static LOCK: Mutex<()> = Mutex::new(());
fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn app(name: &str) -> std::sync::Arc<dyn thapi::apps::Workload> {
    thapi::apps::hecbench::suite()
        .into_iter()
        .chain(thapi::apps::spechpc::suite())
        .find(|a| a.name() == name)
        .unwrap_or_else(|| panic!("app {name}"))
}

/// Decode a registry-class message through `hub` (so the class id
/// resolves on the attach side exactly like a real consumer's would).
fn reg_msg(hub: &LiveHub, name: &str, ts: u64, rank: u32, tid: u32) -> EventMsg {
    let class = thapi::model::class_by_name(name).unwrap();
    hub.decode(rank, tid, class.id, ts, &0u64.to_le_bytes()).unwrap()
}

// ---------------------------------------------------------------------------
// Golden: split one real trace across two TCP publishers; the fan-in
// union must be byte-identical to post-mortem analysis of the whole
// trace (which PR 2/3 pinned byte-identical to a single local --live)
// ---------------------------------------------------------------------------

#[test]
fn fanin_split_trace_over_tcp_is_byte_identical_to_whole_trace_postmortem() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = Node::new(NodeConfig::polaris());
    let r = run(&node, app("513.soma").as_ref(), &IprofConfig::default());
    let trace = r.trace.as_ref().unwrap();
    assert!(trace.streams.len() > 1, "need a multi-stream trace to split");

    // post-mortem reference over the WHOLE trace
    let parsed = analysis::parse_trace(trace).unwrap();
    let mut pm: Vec<Box<dyn AnalysisSink>> =
        vec![Box::new(TallySink::new()), Box::new(TimelineSink::new())];
    let pm_reports = analysis::run_pipeline(&parsed, &mut pm);

    // split the stream set: publisher A gets the first half, B the rest;
    // fan-in connection order A, B makes the shared channel layout the
    // exact concatenation — i.e. the original stream order
    let mid = trace.streams.len() / 2;
    let sub_a = TraceData {
        metadata: trace.metadata.clone(),
        streams: trace.streams[..mid].to_vec(),
    };
    let sub_b = TraceData {
        metadata: trace.metadata.clone(),
        streams: trace.streams[mid..].to_vec(),
    };

    let hub_a = LiveHub::new(&node.config.hostname, 256, false);
    let hub_b = LiveHub::new(&node.config.hostname, 256, false);
    let la = TcpListener::bind("127.0.0.1:0").unwrap();
    let lb = TcpListener::bind("127.0.0.1:0").unwrap();
    let (addr_a, addr_b) = (la.local_addr().unwrap(), lb.local_addr().unwrap());

    let report = std::thread::scope(|s| {
        let (ha, hb) = (&hub_a, &hub_b);
        let (ta, tb) = (&sub_a, &sub_b);
        s.spawn(move || {
            let (conn, _) = la.accept().unwrap();
            publish(ha, conn).unwrap()
        });
        s.spawn(move || {
            let (conn, _) = lb.accept().unwrap();
            publish(hb, conn).unwrap()
        });
        s.spawn(move || replay_trace(ha, ta, 32));
        s.spawn(move || replay_trace(hb, tb, 32));
        let conns = vec![
            TcpStream::connect(addr_a).unwrap(),
            TcpStream::connect(addr_b).unwrap(),
        ];
        let sinks: Vec<Box<dyn AnalysisSink>> =
            vec![Box::new(TallySink::new()), Box::new(TimelineSink::new())];
        run_fanin(conns, 256, sinks, None, |_| {}, &Default::default()).unwrap()
    });

    assert_eq!(report.stats.per.len(), 2);
    assert_eq!(report.failed_publishers(), 0);
    assert_eq!(report.server_dropped(), 0, "lossless replay on both publishers");
    assert_eq!(report.server_received(), trace.record_count());
    assert_eq!(report.latency.merged, trace.record_count());
    assert_eq!(
        report.reports[0].payload(),
        pm_reports[0].payload(),
        "fan-in tally must be byte-identical to whole-trace post-mortem"
    );
    assert_eq!(
        report.reports[1].payload(),
        pm_reports[1].payload(),
        "fan-in timeline must be byte-identical (order-sensitive)"
    );
    // per-publisher accounting splits exactly along the stream split
    let a_events: u64 = sub_a.record_count();
    assert_eq!(report.stats.per[0].server_received, a_events);
    assert_eq!(report.origins[0].received, a_events);
    assert_eq!(
        report.origins[1].received,
        trace.record_count() - a_events,
        "origin accounting covers the rest"
    );
}

// ---------------------------------------------------------------------------
// Golden: synthetic publishers vs a single local --live hub over the
// concatenated stream set (the ISSUE invariant stated directly)
// ---------------------------------------------------------------------------

#[test]
fn fanin_equals_single_local_live_over_concatenated_streams() {
    // publisher A: 2 streams, publisher B: 1 stream — with cross-publisher
    // timestamp ties that the concatenated tie-break must resolve
    let batches_a: Vec<Vec<(u64, u32, u32)>> = vec![
        vec![(10, 0, 1), (15, 0, 1), (20, 0, 1), (25, 0, 1)],
        vec![(10, 0, 2), (17, 0, 2)],
    ];
    let batches_b: Vec<Vec<(u64, u32, u32)>> = vec![vec![(10, 1, 1), (15, 1, 1)]];
    let mk = |hub: &LiveHub, batch: &[(u64, u32, u32)]| -> Vec<EventMsg> {
        batch
            .iter()
            .enumerate()
            .map(|(i, &(ts, rank, tid))| {
                let name = if i % 2 == 0 {
                    "lttng_ust_ze:zeInit_entry"
                } else {
                    "lttng_ust_ze:zeInit_exit"
                };
                reg_msg(hub, name, ts, rank, tid)
            })
            .collect()
    };

    // reference: ONE local hub holding the concatenation A ++ B
    let local = LiveHub::new("fan", 64, false);
    local.ensure_channels(3);
    for (i, b) in batches_a.iter().chain(batches_b.iter()).enumerate() {
        local.push_batch(i, mk(&local, b));
    }
    local.close_all();
    let mut ref_sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let ref_out = run_live_pipeline(LiveSource::new(local), &mut ref_sinks, None, |_| {});

    // fan-in: the same streams split across two publishers
    let wire = |batches: &[Vec<(u64, u32, u32)>]| -> Vec<u8> {
        let hub = LiveHub::new("fan", 64, false);
        hub.ensure_channels(batches.len());
        for (i, b) in batches.iter().enumerate() {
            hub.push_batch(i, mk(&hub, b));
        }
        hub.close_all();
        let mut buf = Vec::new();
        publish(&hub, &mut buf).unwrap();
        buf
    };
    let fan = FanIn::open(
        vec![Cursor::new(wire(&batches_a)), Cursor::new(wire(&batches_b))],
        64,
    )
    .unwrap();
    let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let out = run_live_pipeline(fan.source(), &mut sinks, None, |_| {});
    let stats = fan.finish().unwrap();

    assert_eq!(stats.failed(), 0);
    assert_eq!(stats.server_dropped(), 0);
    assert_eq!(
        out.reports[0].payload(),
        ref_out.reports[0].payload(),
        "fan-in over 2 publishers must equal one local --live over the concatenation"
    );
    assert_eq!(out.latency.merged, 8);
}

// ---------------------------------------------------------------------------
// Golden: a mixed-version fleet (one v3 batched publisher, one v2
// per-event publisher) merges byte-identically to an all-v2 fleet —
// the wire format is an encoding detail, never an ordering input
// ---------------------------------------------------------------------------

#[test]
fn mixed_v3_and_v2_publishers_merge_byte_identically_to_all_v2() {
    // same stream split as the concatenation golden above, including the
    // cross-publisher timestamp ties that expose any merge-order drift
    let batches_a: Vec<Vec<(u64, u32, u32)>> = vec![
        vec![(10, 0, 1), (15, 0, 1), (20, 0, 1), (25, 0, 1)],
        vec![(10, 0, 2), (17, 0, 2)],
    ];
    let batches_b: Vec<Vec<(u64, u32, u32)>> = vec![vec![(10, 1, 1), (15, 1, 1)]];
    let mk = |hub: &LiveHub, batch: &[(u64, u32, u32)]| -> Vec<EventMsg> {
        batch
            .iter()
            .enumerate()
            .map(|(i, &(ts, rank, tid))| {
                let name = if i % 2 == 0 {
                    "lttng_ust_ze:zeInit_entry"
                } else {
                    "lttng_ust_ze:zeInit_exit"
                };
                reg_msg(hub, name, ts, rank, tid)
            })
            .collect()
    };
    let wire = |batches: &[Vec<(u64, u32, u32)>], version: u32| -> Vec<u8> {
        let hub = LiveHub::new("fan", 64, false);
        hub.ensure_channels(batches.len());
        for (i, b) in batches.iter().enumerate() {
            hub.push_batch(i, mk(&hub, b));
        }
        hub.close_all();
        let mut buf = Vec::new();
        publish_with(&hub, &mut buf, version).unwrap();
        buf
    };
    let run_pair = |ver_a: u32, ver_b: u32| {
        let fan = FanIn::open(
            vec![
                Cursor::new(wire(&batches_a, ver_a)),
                Cursor::new(wire(&batches_b, ver_b)),
            ],
            64,
        )
        .unwrap();
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let out = run_live_pipeline(fan.source(), &mut sinks, None, |_| {});
        let origins = fan.hub().origin_stats();
        let stats = fan.finish().unwrap();
        (out, origins, stats)
    };

    let (ref_out, ref_origins, ref_stats) = run_pair(2, 2);
    assert_eq!(ref_stats.failed(), 0);
    assert!(
        ref_origins.iter().all(|o| o.wire_version == 2 && o.batches == 0),
        "the all-v2 reference fleet must be batch-free: {ref_origins:?}"
    );

    let (out, origins, stats) = run_pair(3, 2);
    assert_eq!(stats.failed(), 0);
    assert_eq!(stats.server_dropped(), 0);
    assert_eq!(
        out.reports[0].payload(),
        ref_out.reports[0].payload(),
        "a mixed v3/v2 fleet must merge byte-identically to an all-v2 fleet"
    );
    assert_eq!(out.latency.merged, ref_out.latency.merged);
    // the negotiation outcome is visible per origin: A batched, B fell back
    assert_eq!((origins[0].wire_version, origins[1].wire_version), (3, 2));
    assert!(origins[0].batches >= 1, "the v3 origin arrived batched: {origins:?}");
    assert_eq!(origins[1].batches, 0, "the v2 origin stayed per-event: {origins:?}");
    assert_eq!((stats.per[0].wire_version, stats.per[1].wire_version), (3, 2));
    // and event accounting is identical on both wires
    assert_eq!(stats.per[0].events, ref_stats.per[0].events);
    assert_eq!(stats.per[1].events, ref_stats.per[1].events);
}

// ---------------------------------------------------------------------------
// Property: randomized publishers, streams, ties, run interleavings —
// the fan-in merge equals the post-mortem merge of the concatenation
// ---------------------------------------------------------------------------

#[test]
fn prop_fanin_merge_order_equals_concatenated_postmortem_merge() {
    prop::check(20, 0xfa71, |rng| {
        let class = Arc::new(DecodedClass {
            id: 0,
            name: "lttng_ust_ze:zeInit_entry".to_string(),
            api: "ZE".to_string(),
            flags: "h".to_string(),
            fields: vec![],
        });
        let hostname: Arc<str> = Arc::from("fan");
        let n_pubs = rng.range(2, 5);
        // publisher p -> its own list of streams of (ts-tied) events
        let mut pubs: Vec<Vec<Vec<EventMsg>>> = Vec::with_capacity(n_pubs);
        for p in 0..n_pubs {
            let n_streams = rng.range(1, 4);
            let mut streams = Vec::with_capacity(n_streams);
            for si in 0..n_streams {
                let mut ts = rng.below(4);
                let n = rng.range(0, 30);
                let mut events = Vec::with_capacity(n);
                for i in 0..n {
                    ts += rng.below(3); // zero increments force equal timestamps
                    events.push(EventMsg {
                        ts,
                        rank: p as u32,
                        tid: (si * 1000 + i) as u32,
                        hostname: hostname.clone(),
                        class: class.clone(),
                        fields: vec![],
                    });
                }
                streams.push(events);
            }
            pubs.push(streams);
        }

        // expected: post-mortem merge over the CONCATENATED stream set
        let concat = ParsedTrace {
            metadata: Metadata::default(),
            streams: pubs.iter().flat_map(|s| s.iter().cloned()).collect(),
        };
        let expected: Vec<(u64, u32, u32)> =
            MessageSource::new(&concat).map(|m| (m.ts, m.rank, m.tid)).collect();

        // one hand-built wire per publisher: random-length per-stream runs
        // with honest watermark beacons, then closes and Eos
        let md = "btf_version: 1\nenv:\nevents:\n  - id: 0\n    \
                  name: lttng_ust_ze:zeInit_entry\n    api: ZE\n    flags: h\n    fields:\n";
        let mut wires = Vec::with_capacity(n_pubs);
        for streams in &pubs {
            let mut wire = Vec::new();
            frame::write_preamble(&mut wire).unwrap();
            frame::write_frame(
                &mut wire,
                &Frame::Hello {
                    hostname: "fan".into(),
                    metadata: md.to_string(),
                    streams: streams.len() as u32,
                    epoch: 0,
                },
            )
            .unwrap();
            let mut cursor = vec![0usize; streams.len()];
            loop {
                let mut progressed = false;
                for (i, s) in streams.iter().enumerate() {
                    if cursor[i] >= s.len() {
                        continue;
                    }
                    progressed = true;
                    let run = rng.range(1, 6).min(s.len() - cursor[i]);
                    for m in &s[cursor[i]..cursor[i] + run] {
                        frame::write_frame(
                            &mut wire,
                            &Frame::Event {
                                stream: i as u32,
                                event: WireEvent {
                                    ts: m.ts,
                                    rank: m.rank,
                                    tid: m.tid,
                                    class_id: 0,
                                    fields: vec![],
                                },
                            },
                        )
                        .unwrap();
                    }
                    cursor[i] += run;
                    if let Some(next) = s.get(cursor[i]) {
                        frame::write_frame(
                            &mut wire,
                            &Frame::Beacon { stream: i as u32, watermark: next.ts },
                        )
                        .unwrap();
                    }
                }
                if !progressed {
                    break;
                }
            }
            for i in 0..streams.len() {
                frame::write_frame(&mut wire, &Frame::Close { stream: i as u32 }).unwrap();
            }
            let total: u64 = streams.iter().map(|s| s.len() as u64).sum();
            frame::write_frame(&mut wire, &Frame::Eos { received: total, dropped: 0 })
                .unwrap();
            wires.push(wire);
        }

        let fan =
            FanIn::open(wires.into_iter().map(Cursor::new).collect::<Vec<_>>(), 8).unwrap();
        let got: Vec<(u64, u32, u32)> = fan.source().map(|m| (m.ts, m.rank, m.tid)).collect();
        let stats = fan.finish().unwrap();
        assert_eq!(stats.failed(), 0);
        assert_eq!(
            got, expected,
            "fan-in merge must equal the concatenated post-mortem merge exactly"
        );
    });
}

// ---------------------------------------------------------------------------
// Failure isolation: a killed publisher degrades the union to a
// partial-but-correct analysis with exact per-publisher accounting
// ---------------------------------------------------------------------------

#[test]
fn killed_publisher_yields_partial_union_analysis_with_accounting() {
    // publisher A: complete session, 4 events, clean Eos
    let hub_a = LiveHub::new("alive", 64, false);
    hub_a.ensure_channels(1);
    hub_a.push_batch(
        0,
        vec![
            reg_msg(&hub_a, "lttng_ust_ze:zeInit_entry", 10, 0, 1),
            reg_msg(&hub_a, "lttng_ust_ze:zeInit_exit", 15, 0, 1),
            reg_msg(&hub_a, "lttng_ust_ze:zeInit_entry", 20, 0, 1),
            reg_msg(&hub_a, "lttng_ust_ze:zeInit_exit", 25, 0, 1),
        ],
    );
    hub_a.close_all();
    let mut wire_a = Vec::new();
    publish(&hub_a, &mut wire_a).unwrap();

    // publisher B: 2 complete events, then killed mid-frame (no Eos)
    let mut wire_b = Vec::new();
    frame::write_preamble(&mut wire_b).unwrap();
    frame::write_frame(
        &mut wire_b,
        &Frame::Hello {
            hostname: "dying".into(),
            metadata: generate_metadata(&[]),
            streams: 1,
            epoch: 0,
        },
    )
    .unwrap();
    let entry_id = thapi::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap().id;
    for ts in [12u64, 17] {
        frame::write_frame(
            &mut wire_b,
            &Frame::Event {
                stream: 0,
                event: WireEvent {
                    ts,
                    rank: 1,
                    tid: 9,
                    class_id: entry_id,
                    fields: vec![thapi::tracer::encoder::FieldValue::U64(0)],
                },
            },
        )
        .unwrap();
    }
    let mut cut_frame = Vec::new();
    frame::write_frame(
        &mut cut_frame,
        &Frame::Beacon { stream: 0, watermark: 99 },
    )
    .unwrap();
    wire_b.extend_from_slice(&cut_frame[..cut_frame.len() / 2]); // the kill

    let sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let report = run_fanin(
        vec![Cursor::new(wire_a), Cursor::new(wire_b)],
        64,
        sinks,
        None,
        |_| {},
        &Default::default(),
    )
    .unwrap();

    // the union analysis survived and covers A fully + B up to the cut
    assert_eq!(report.reports.len(), 1, "partial report produced, not discarded");
    assert!(report.reports[0].payload().unwrap().contains("zeInit"));
    assert_eq!(report.latency.merged, 6, "4 from A + 2 from B before the cut");
    // per-publisher accounting: A clean, B dead with its partial counts
    assert_eq!(report.failed_publishers(), 1);
    assert!(report.stats.per[0].error.is_none());
    assert_eq!(report.stats.per[0].server_received, 4, "A's Eos accounting intact");
    assert_eq!(report.stats.per[0].server_dropped, 0);
    let dead = &report.stats.per[1];
    assert!(dead.error.is_some(), "{dead:?}");
    assert_eq!(dead.events, 2, "B's frames before the cut are counted");
    assert_eq!(dead.server_received, 0, "no Eos ever arrived from B");
    assert_eq!(report.origins[0].received, 4);
    assert_eq!(report.origins[1].received, 2);
    assert!(report.origins[1].eos.is_none(), "B died before Eos");
    assert_eq!(report.origins[0].eos, Some((4, 0)));
    assert_eq!(report.hostnames, vec!["alive".to_string(), "dying".to_string()]);
}

// ---------------------------------------------------------------------------
// Stream-id collision: identical per-publisher ids must not alias
// ---------------------------------------------------------------------------

#[test]
fn colliding_stream_ids_across_publishers_do_not_alias() {
    // both publishers use stream id 0 AND the same timestamp: without
    // origin namespacing the second feed would interleave into the first
    // publisher's channel (the pre-fan-in latent bug)
    let wire = |rank: u32| -> Vec<u8> {
        let hub = LiveHub::new(&format!("node{rank}"), 8, false);
        hub.ensure_channels(1);
        hub.push_batch(
            0,
            vec![
                reg_msg(&hub, "lttng_ust_ze:zeInit_entry", 100, rank, rank),
                reg_msg(&hub, "lttng_ust_ze:zeInit_exit", 200, rank, rank),
            ],
        );
        hub.close_all();
        let mut buf = Vec::new();
        publish(&hub, &mut buf).unwrap();
        buf
    };
    let fan = FanIn::open(vec![Cursor::new(wire(0)), Cursor::new(wire(1))], 8).unwrap();
    let merged: Vec<(u64, u32)> = fan.source().map(|m| (m.ts, m.rank)).collect();
    // all four events survive; equal timestamps order by connection order
    assert_eq!(merged, vec![(100, 0), (100, 1), (200, 0), (200, 1)]);
    let origins = fan.hub().origin_stats();
    assert_eq!(origins.len(), 2);
    assert_eq!((origins[0].received, origins[1].received), (2, 2));
    assert_eq!(origins[0].label, "node0");
    assert_eq!(origins[1].label, "node1");
    let stats = fan.finish().unwrap();
    assert_eq!(stats.server_received(), 4);
}

// ---------------------------------------------------------------------------
// Reconnect/resume goldens: a killed-and-resumed publisher is
// byte-identical to an uninterrupted run; a ring overflow books its gap
// into the per-origin Drops ledger instead of dying
// ---------------------------------------------------------------------------

/// Serve one resumable session over TCP until the wire reaches Eos:
/// accept, optionally kill the FIRST connection after `kill_first_after`
/// written bytes (fault injection), and keep accepting so the
/// subscriber can resume.
fn serve_resumable_publisher(
    listener: TcpListener,
    hub: Arc<LiveHub>,
    epoch: u64,
    resume_buffer: usize,
    kill_first_after: Option<usize>,
) -> PublishStats {
    let mut publisher = Publisher::new(hub, epoch, resume_buffer);
    let mut kill = kill_first_after;
    loop {
        let (conn, _) = listener.accept().unwrap();
        let conn = KillAfter::new(conn, kill.take().unwrap_or(usize::MAX));
        match publisher.serve_connection(conn) {
            ServeOutcome::Complete => return publisher.stats(),
            ServeOutcome::Lost(_) => continue,
        }
    }
}

/// Wire size of the Hello a resumable publisher sends for `streams`
/// channels — lets a test aim its kill budget past the handshake and
/// into the event stream.
fn hello_wire_len(hostname: &str, streams: u32, epoch: u64) -> usize {
    let mut buf = Vec::new();
    thapi::remote::encode(
        &Frame::Hello {
            hostname: hostname.into(),
            metadata: generate_metadata(&[]),
            streams,
            epoch,
        },
        &mut buf,
    );
    buf.len()
}

#[test]
fn killed_and_resumed_publisher_is_byte_identical_to_uninterrupted_run() {
    // publisher A: two streams; publisher B: one stream, with timestamps
    // interleaved (and tied) against A's so any ordering drift after the
    // resume would show up in the merged tuple sequence
    let batches_a: Vec<Vec<(u64, u32)>> = vec![
        vec![(10, 1), (15, 1), (20, 1), (25, 1), (30, 1), (35, 1)],
        vec![(12, 2), (17, 2), (22, 2)],
    ];
    let batches_b: Vec<Vec<(u64, u32)>> = vec![vec![(10, 9), (16, 9), (21, 9), (26, 9), (31, 9)]];
    let fill = |hostname: &str, batches: &[Vec<(u64, u32)>]| -> Arc<LiveHub> {
        let hub = LiveHub::new(hostname, 64, false);
        hub.ensure_channels(batches.len());
        for (i, b) in batches.iter().enumerate() {
            let msgs = b
                .iter()
                .enumerate()
                .map(|(j, &(ts, tid))| {
                    let name = if j % 2 == 0 {
                        "lttng_ust_ze:zeInit_entry"
                    } else {
                        "lttng_ust_ze:zeInit_exit"
                    };
                    reg_msg(&hub, name, ts, 0, tid)
                })
                .collect();
            hub.push_batch(i, msgs);
        }
        hub.close_all();
        hub
    };

    // kill B's first connection a few events past the handshake: the cut
    // lands mid-event-stream (possibly mid-frame), which is exactly what
    // resumption must absorb
    let kill_at = 8 + hello_wire_len("nodeB", 1, 0xB0B) + 150;

    let mut run_once = |kill_b: Option<usize>| {
        let la = TcpListener::bind("127.0.0.1:0").unwrap();
        let lb = TcpListener::bind("127.0.0.1:0").unwrap();
        let (addr_a, addr_b) = (la.local_addr().unwrap(), lb.local_addr().unwrap());
        let hub_a = fill("nodeA", &batches_a);
        let hub_b = fill("nodeB", &batches_b);
        std::thread::scope(|s| {
            s.spawn(move || serve_resumable_publisher(la, hub_a, 0xA11CE, 1 << 20, None));
            s.spawn(move || serve_resumable_publisher(lb, hub_b, 0xB0B, 1 << 20, kill_b));
            let mk = |addr: std::net::SocketAddr| move || TcpStream::connect(addr);
            let fan = FanIn::open_resumable(
                vec![mk(addr_a), mk(addr_b)],
                64,
                ReconnectPolicy { attempts: 8, backoff: Duration::from_millis(10) },
            )
            .unwrap();
            let merged: Vec<(u64, u32, u32)> =
                fan.source().map(|m| (m.ts, m.rank, m.tid)).collect();
            let gaps = fan.hub().origin_stats().iter().map(|o| o.resume_gaps).sum::<u64>();
            let stats = fan.finish().unwrap();
            (merged, stats, gaps)
        })
    };

    let (reference, ref_stats, ref_gaps) = run_once(None);
    assert_eq!(ref_stats.reconnects(), 0);
    assert_eq!(ref_gaps, 0);
    assert_eq!(reference.len(), 14, "6 + 3 from A, 5 from B");

    let (resumed, stats, gaps) = run_once(Some(kill_at));
    assert_eq!(stats.failed(), 0, "the killed publisher resumed, nobody died: {stats:?}");
    assert!(stats.per[1].reconnects >= 1, "B's connection was killed and re-joined: {stats:?}");
    assert_eq!(gaps, 0, "a roomy ring replays everything — no gap");
    assert_eq!(stats.server_dropped(), 0);
    assert_eq!(
        resumed, reference,
        "a killed-and-resumed publisher must merge byte-identically to an uninterrupted run"
    );
}

#[test]
fn ring_overflow_books_gap_into_drops_ledger_and_fails_strict() {
    // one stream, 40 events; the replay ring only holds ~3 event frames,
    // and the first connection dies well past what the ring can keep —
    // the resume MUST come back with a gap, not an error
    let n_events = 40u64;
    let hub = LiveHub::new("lossyring", 64, false);
    hub.ensure_channels(1);
    let msgs: Vec<EventMsg> = (0..n_events)
        .map(|i| {
            let name = if i % 2 == 0 {
                "lttng_ust_ze:zeInit_entry"
            } else {
                "lttng_ust_ze:zeInit_exit"
            };
            reg_msg(&hub, name, 10 + i * 5, 0, 1)
        })
        .collect();
    hub.push_batch(0, msgs);
    hub.close_all();

    // one encoded event frame, to size the ring in whole events
    let event_len = {
        let mut buf = Vec::new();
        thapi::remote::encode(
            &Frame::Event {
                stream: 0,
                event: WireEvent {
                    ts: 10,
                    rank: 0,
                    tid: 1,
                    class_id: thapi::model::class_by_name("lttng_ust_ze:zeInit_entry")
                        .unwrap()
                        .id,
                    fields: vec![thapi::tracer::encoder::FieldValue::U64(0)],
                },
            },
            &mut buf,
        );
        buf.len()
    };
    let ring_budget = 3 * event_len;
    let kill_at = 8 + hello_wire_len("lossyring", 1, 0x10557) + 20 * event_len;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (report, publish_stats) = std::thread::scope(|s| {
        let server = s.spawn(move || {
            serve_resumable_publisher(listener, hub, 0x10557, ring_budget, Some(kill_at))
        });
        let sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let report = run_fanin_resumable(
            vec![move || TcpStream::connect(addr)],
            64,
            ReconnectPolicy { attempts: 8, backoff: Duration::from_millis(10) },
            sinks,
            None,
            |_| {},
            &Default::default(),
        )
        .unwrap();
        (report, server.join().unwrap())
    });

    // non-strict semantics: the run COMPLETES, with the gap accounted
    assert_eq!(report.failed_publishers(), 0, "{:?}", report.stats);
    assert!(report.reconnects() >= 1);
    assert_eq!(report.reports.len(), 1, "analysis completed over everything recoverable");
    let gap = report.resume_gaps();
    assert!(gap > 0, "a 3-event ring cannot cover the outage: {report:?}");
    assert_eq!(
        report.origins[0].resume_gaps, gap,
        "the gap lands in the per-origin Drops ledger"
    );
    assert_eq!(publish_stats.gaps, gap, "both ends agree on the exact loss");
    assert_eq!(
        report.latency.merged,
        n_events - gap,
        "everything outside the gap was merged exactly once"
    );
    // strict semantics: the gate iprof attach --live-strict applies
    assert!(
        report.known_dropped() >= gap && report.known_dropped() > 0,
        "--live-strict must fail on a resume gap (known_dropped {})",
        report.known_dropped()
    );
}

// ---------------------------------------------------------------------------
// Property: the reconnect backoff schedule is safe at every point of
// the (backoff, attempt) space — monotone non-decreasing, capped at
// 5 s even for absurd base backoffs, saturated past attempt 16, and
// exactly doubling while below the cap
// ---------------------------------------------------------------------------

#[test]
fn reconnect_delay_is_monotone_capped_and_doubling() {
    let cap = Duration::from_secs(5);
    prop::check(200, 0xbac0ff, |rng| {
        // sweep from sub-millisecond bases to bases already above the
        // cap (a hostile config must still respect the ceiling)
        let backoff = match rng.below(3) {
            0 => Duration::from_micros(1 + rng.below(5_000)),
            1 => Duration::from_millis(1 + rng.below(2_000)),
            _ => Duration::from_secs(1 + rng.below(100)),
        };
        let attempts = 1 + rng.below(64) as u32;
        let policy = ReconnectPolicy { attempts, backoff };

        let mut prev = Duration::ZERO;
        let mut total = Duration::ZERO;
        for attempt in 0..attempts.max(20) {
            let d = policy.delay(attempt);
            assert!(d <= cap, "delay({attempt}) = {d:?} exceeds the 5 s cap ({backoff:?})");
            assert!(d >= prev, "delay must never shrink: delay({attempt}) = {d:?} < {prev:?}");
            if d < cap && attempt < 16 {
                assert_eq!(
                    policy.delay(attempt + 1),
                    cap.min(d * 2),
                    "below the cap the backoff doubles exactly ({backoff:?}, attempt {attempt})"
                );
            }
            if attempt >= 16 {
                assert_eq!(
                    d,
                    policy.delay(16),
                    "the exponent saturates at 16: no overflow wrap-around past it"
                );
            }
            prev = d;
            if attempt < attempts {
                total += d;
            }
        }
        // an outage's worth of redials is time-bounded by attempts × cap
        assert!(
            total <= cap * attempts,
            "sleeping out a full budget of {attempts} attempts must stay under {:?}, got {total:?}",
            cap * attempts
        );
    });
}
