//! Remote live viewer tests: codec round-trip property, loopback
//! byte-identity, drop accounting, and the whole serve/attach stack.
//!
//! The acceptance bar: `iprof serve --live` + `iprof attach` over a real
//! socket must produce sink output **byte-identical** to local
//! `iprof --live` (and therefore to post-mortem analysis) for lossless
//! feeds, with drop counts surfaced on both ends when feeds are lossy.

use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex, MutexGuard};
use thapi::analysis::{self, AnalysisSink, TallySink, TimelineSink};
use thapi::coordinator::{run_attach, run_serve, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::live::{replay_trace, LiveConfig, LiveHub, LiveSource};
use thapi::remote::{
    decode, encode, publish, Attachment, BatchEvent, BatchKey, Frame, WireEvent,
    MAX_DICT_ENTRIES,
};
use thapi::tracer::encoder::FieldValue;
use thapi::util::{prop, Rng};

/// Global-session tests cannot overlap.
static LOCK: Mutex<()> = Mutex::new(());
fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn app(name: &str) -> std::sync::Arc<dyn thapi::apps::Workload> {
    thapi::apps::hecbench::suite()
        .into_iter()
        .chain(thapi::apps::spechpc::suite())
        .find(|a| a.name() == name)
        .unwrap_or_else(|| panic!("app {name}"))
}

// ---------------------------------------------------------------------------
// Property: decode(encode(frame)) round-trips for arbitrary frames
// ---------------------------------------------------------------------------

fn arbitrary_field(rng: &mut Rng) -> FieldValue {
    match rng.below(5) {
        0 => FieldValue::U64(rng.next_u64()),
        1 => FieldValue::I64(rng.next_u64() as i64),
        // finite values only: the equality below goes through PartialEq,
        // under which NaN != NaN; NaN bit-exactness is covered by the
        // codec's own unit tests
        2 => FieldValue::F64((rng.next_u64() as i64 as f64) / 1024.0),
        3 => FieldValue::Ptr(rng.next_u64()),
        _ => {
            let n = rng.range(0, 64);
            let s: String = (0..n)
                .map(|_| char::from_u32(0x20 + rng.below(0x5e) as u32).unwrap())
                .collect();
            FieldValue::Str(s)
        }
    }
}

fn arbitrary_batch_event(rng: &mut Rng) -> BatchEvent {
    BatchEvent {
        // arbitrary u64 timestamps: deltas are zigzag-wrapped, so even
        // wildly non-monotone sequences must round-trip exactly
        ts: rng.next_u64(),
        key: if rng.below(2) == 0 {
            BatchKey::Def {
                rank: rng.next_u64() as u32,
                tid: rng.next_u64() as u32,
                class_id: rng.next_u64() as u32,
            }
        } else {
            BatchKey::Ref(rng.below(u64::from(MAX_DICT_ENTRIES)) as u32)
        },
        fields: (0..rng.range(0, 6)).map(|_| arbitrary_field(rng)).collect(),
    }
}

fn arbitrary_frame(rng: &mut Rng) -> Frame {
    match rng.below(11) {
        0 => {
            let n = rng.range(0, 512);
            let metadata: String = (0..n)
                .map(|_| char::from_u32(0x20 + rng.below(0x5e) as u32).unwrap())
                .collect();
            Frame::Hello {
                hostname: format!("node{}", rng.below(1000)),
                metadata,
                streams: rng.next_u64() as u32,
                epoch: rng.next_u64(),
            }
        }
        1 => Frame::Streams { count: rng.next_u64() as u32 },
        2 => Frame::Event {
            stream: rng.below(1 << 16) as u32,
            event: WireEvent {
                ts: rng.next_u64(),
                rank: rng.next_u64() as u32,
                tid: rng.next_u64() as u32,
                class_id: rng.next_u64() as u32,
                fields: (0..rng.range(0, 12)).map(|_| arbitrary_field(rng)).collect(),
            },
        },
        3 => Frame::Beacon { stream: rng.below(1 << 16) as u32, watermark: rng.next_u64() },
        4 => Frame::Drops { stream: rng.below(1 << 16) as u32, dropped: rng.next_u64() },
        5 => Frame::Close { stream: rng.below(1 << 16) as u32 },
        6 => Frame::Resume {
            epoch: rng.next_u64(),
            cursors: (0..rng.range(0, 9)).map(|_| rng.next_u64()).collect(),
        },
        7 => Frame::ResumeGap { stream: rng.below(1 << 16) as u32, missed: rng.next_u64() },
        8 => Frame::EventBatch {
            stream: rng.below(1 << 16) as u32,
            events: (0..rng.range(0, 9)).map(|_| arbitrary_batch_event(rng)).collect(),
        },
        9 => Frame::Origin {
            path: format!("{}:relay{}/{}:node{}", rng.below(8), rng.below(8), rng.below(8), rng.below(8)),
            hostname: format!("node{}", rng.below(1000)),
            streams: (0..rng.range(0, 9)).map(|_| rng.below(1 << 16) as u32).collect(),
            dropped: rng.next_u64(),
            resume_gaps: rng.next_u64(),
            eos: if rng.below(2) == 0 { None } else { Some((rng.next_u64(), rng.next_u64())) },
        },
        _ => Frame::Eos { received: rng.next_u64(), dropped: rng.next_u64() },
    }
}

/// `decode(encode(f)) == f` for arbitrary frames, alone and back-to-back
/// in one buffer, and every strict prefix reads as "incomplete", never as
/// a wrong frame.
#[test]
fn prop_frame_codec_roundtrips_arbitrary_frames() {
    prop::check(200, 0x2e07e, |rng| {
        let frames: Vec<Frame> = (0..rng.range(1, 8)).map(|_| arbitrary_frame(rng)).collect();
        let mut wire = Vec::new();
        for f in &frames {
            encode(f, &mut wire);
        }
        // sequential decode returns the exact frame sequence
        let mut off = 0;
        let mut got = Vec::new();
        while off < wire.len() {
            let (f, n) = decode(&wire[off..]).expect("valid wire").expect("complete frame");
            assert!(n > 4, "every frame consumes its length prefix and body");
            got.push(f);
            off += n;
        }
        assert_eq!(off, wire.len());
        assert_eq!(got, frames);
        // a strict prefix of the first frame is incomplete, not corrupt
        let (_, first_len) = decode(&wire).unwrap().unwrap();
        let cut = rng.range(0, first_len);
        assert_eq!(decode(&wire[..cut]).expect("prefix is not an error"), None);
    });
}

// ---------------------------------------------------------------------------
// Loopback: replayed trace through serve/attach == local live == post-mortem
// ---------------------------------------------------------------------------

/// The acceptance-criteria core: a lossless replayed trace published over
/// a real TCP socket and analyzed by `attach` produces tally output
/// byte-identical to the local `--live` replay AND to post-mortem
/// analysis of the same trace.
#[test]
fn attach_tally_is_byte_identical_to_local_live_and_postmortem() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = Node::new(NodeConfig::test_small());
    let r = thapi::coordinator::run(&node, app("saxpy-ze").as_ref(), &IprofConfig::default());
    let trace = r.trace.as_ref().unwrap();

    // post-mortem reference
    let parsed = analysis::parse_trace(trace).unwrap();
    let mut pm: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let pm_reports = analysis::run_pipeline(&parsed, &mut pm);
    let pm_text = pm_reports[0].payload().unwrap().to_string();

    // local live replay reference (lossless blocking feed)
    let local_hub = LiveHub::new(&node.config.hostname, 64, false);
    let local_source = LiveSource::new(local_hub.clone());
    let local_text = std::thread::scope(|s| {
        let feeder = s.spawn(|| replay_trace(&local_hub, trace, 16));
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let out = thapi::live::run_live_pipeline(local_source, &mut sinks, None, |_| {});
        feeder.join().unwrap();
        out.reports[0].payload().unwrap().to_string()
    });
    assert_eq!(local_text, pm_text, "precondition: local live equals post-mortem");

    // remote: replay into a serve-side hub, publish over TCP, attach here
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let serve_hub = LiveHub::new(&node.config.hostname, 64, false);
    let (attach_report, publish_stats) = std::thread::scope(|s| {
        let hub = &serve_hub;
        let publisher = s.spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            publish(hub, conn).unwrap()
        });
        let feeder = s.spawn(move || replay_trace(hub, trace, 16));
        let conn = TcpStream::connect(addr).unwrap();
        let sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let report = run_attach(conn, 64, sinks, None, |_| {}).unwrap();
        feeder.join().unwrap();
        (report, publisher.join().unwrap())
    });

    assert_eq!(
        attach_report.reports[0].payload().unwrap(),
        pm_text,
        "remote tally must be byte-identical to post-mortem (and local live)"
    );
    assert_eq!(attach_report.remote.server_dropped, 0, "lossless replay");
    assert_eq!(attach_report.remote.server_received, trace.record_count());
    assert_eq!(attach_report.latency.merged, trace.record_count());
    assert_eq!(publish_stats.events, trace.record_count());
    assert_eq!(attach_report.local.dropped, 0, "the attach feed never drops");
}

/// Same bar for the full two-sink shape over an in-memory wire: the
/// remote merge must reproduce the exact (ts, stream, seq) order, which
/// timeline output is sensitive to.
#[test]
fn attach_tally_and_timeline_match_postmortem_over_memory_wire() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = Node::new(NodeConfig::polaris());
    let r = thapi::coordinator::run(&node, app("513.soma").as_ref(), &IprofConfig::default());
    let trace = r.trace.as_ref().unwrap();
    assert!(trace.streams.len() > 1, "need a multi-stream trace");

    let parsed = analysis::parse_trace(trace).unwrap();
    let mut pm: Vec<Box<dyn AnalysisSink>> =
        vec![Box::new(TallySink::new()), Box::new(TimelineSink::new())];
    let pm_reports = analysis::run_pipeline(&parsed, &mut pm);

    // publish a lossless replay into a Vec<u8>, then attach from it —
    // the codec alone carries the whole session
    let hub = LiveHub::new(&node.config.hostname, 256, false);
    let wire = std::thread::scope(|s| {
        let feeder = s.spawn(|| replay_trace(&hub, trace, 32));
        let mut buf = Vec::new();
        publish(&hub, &mut buf).unwrap();
        feeder.join().unwrap();
        buf
    });

    let att = Attachment::open(std::io::Cursor::new(wire), 256).unwrap();
    let mut sinks: Vec<Box<dyn AnalysisSink>> =
        vec![Box::new(TallySink::new()), Box::new(TimelineSink::new())];
    let out = thapi::live::run_live_pipeline(att.source(), &mut sinks, None, |_| {});
    let stats = att.finish().unwrap();
    assert_eq!(stats.server_dropped, 0);
    assert_eq!(out.reports[0].payload(), pm_reports[0].payload(), "tally byte-identical");
    assert_eq!(out.reports[1].payload(), pm_reports[1].payload(), "timeline byte-identical");
}

// ---------------------------------------------------------------------------
// Whole stack: run_serve + run_attach with a real traced workload
// ---------------------------------------------------------------------------

#[test]
fn serve_and_attach_whole_stack_matches_postmortem_of_retained_trace() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = Node::new(NodeConfig::test_small());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // deep channels (no drops) + retain so the identical run feeds both paths
    let live_cfg = LiveConfig { channel_depth: 1 << 16, retain: true, refresh: None };

    let (serve_report, attach_report) = std::thread::scope(|s| {
        let node_ref = &node;
        let cfg_ref = &live_cfg;
        let server = s.spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            run_serve(
                node_ref,
                app("saxpy-ze").as_ref(),
                &IprofConfig::default(),
                cfg_ref,
                conn,
                thapi::remote::VERSION,
                &Default::default(),
            )
            .unwrap()
        });
        let conn = TcpStream::connect(addr).unwrap();
        let sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let attach = run_attach(conn, 1 << 16, sinks, None, |_| {}).unwrap();
        (server.join().unwrap(), attach)
    });

    assert_eq!(serve_report.total_dropped(), 0, "deep channels must not drop");
    assert!(serve_report.stats.written > 50);
    assert_eq!(serve_report.publish.events, serve_report.stats.written);
    assert_eq!(attach_report.remote.server_received, serve_report.stats.written);
    assert_eq!(attach_report.latency.merged, serve_report.stats.written);
    assert_eq!(attach_report.hostname, node.config.hostname);

    let parsed = analysis::parse_trace(serve_report.trace.as_ref().unwrap()).unwrap();
    let mut pm: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let pm_reports = analysis::run_pipeline(&parsed, &mut pm);
    assert_eq!(
        attach_report.reports[0].payload(),
        pm_reports[0].payload(),
        "remote on-line tally must be byte-identical to post-mortem of the same run"
    );
}

// ---------------------------------------------------------------------------
// Drop accounting: lossy feeds are visible on both ends
// ---------------------------------------------------------------------------

#[test]
fn lossy_publisher_surfaces_drop_counts_to_the_subscriber() {
    // depth-2 hub, nothing draining during the pushes: most messages drop
    // at the publisher and the subscriber must learn the exact count
    let hub = LiveHub::new("lossy", 2, false);
    hub.ensure_channels(1);
    let class = thapi::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
    let n: u64 = 50;
    for i in 0..n {
        let msg = hub.decode(0, 0, class.id, i, &0u64.to_le_bytes()).unwrap();
        hub.push_batch(0, vec![msg]);
    }
    hub.close_all();
    let server_stats = hub.stats();
    assert_eq!(server_stats.received, 2);
    assert_eq!(server_stats.dropped, n - 2, "publisher end: drops counted");

    let mut wire = Vec::new();
    publish(&hub, &mut wire).unwrap();
    let att = Attachment::open(std::io::Cursor::new(wire), 8).unwrap();
    let merged = att.source().count();
    let stats = att.finish().unwrap();
    assert_eq!(merged, 2, "only the surviving messages arrive");
    assert_eq!(stats.server_dropped, n - 2, "subscriber end: drops surfaced");
    assert_eq!(stats.server_received, 2);
}

/// A publisher that dies before Eos must still yield the partial
/// analysis of everything received — that is the point of watching a
/// run live — with the transport error surfaced in the stats.
#[test]
fn dying_publisher_still_yields_partial_reports() {
    let hub = LiveHub::new("dying", 64, false);
    hub.ensure_channels(1);
    let class = thapi::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
    for i in 0..10 {
        let msg = hub.decode(0, 0, class.id, i, &0u64.to_le_bytes()).unwrap();
        hub.push_batch(0, vec![msg]);
    }
    hub.close_all();
    let mut wire = Vec::new();
    publish(&hub, &mut wire).unwrap();
    // cut the connection mid-stream: drop the Eos frame and then some
    wire.truncate(wire.len() - 20);

    let att = Attachment::open(std::io::Cursor::new(wire), 64).unwrap();
    let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let out = thapi::live::run_live_pipeline(att.source(), &mut sinks, None, |_| {});
    let stats = att.finish().unwrap();
    assert!(stats.error.is_some(), "the cut must be surfaced: {stats:?}");
    assert!(out.latency.merged > 0, "events before the cut were still analyzed");
    assert_eq!(out.reports.len(), 1, "partial report produced, not discarded");
    assert!(out.reports[0].payload().unwrap().contains("zeInit"));
}

// ---------------------------------------------------------------------------
// Ordering: the remote merge reproduces the live tie-break exactly
// ---------------------------------------------------------------------------

/// Randomized multi-stream feeds with deliberate timestamp ties: the
/// subscriber's merged (ts, rank, tid) sequence equals the post-mortem
/// MessageSource order — through the wire.
#[test]
fn prop_remote_merge_order_equals_postmortem_merge() {
    use thapi::analysis::{EventMsg, MessageSource, ParsedTrace};
    use thapi::tracer::btf::{DecodedClass, Metadata};

    prop::check(25, 0x27e40, |rng| {
        let class = Arc::new(DecodedClass {
            id: 0,
            name: "lttng_ust_ze:zeInit_entry".to_string(),
            api: "ZE".to_string(),
            flags: "h".to_string(),
            fields: vec![],
        });
        let hostname: Arc<str> = Arc::from("remotenode");
        let n_streams = rng.range(1, 6);
        let mut streams = Vec::with_capacity(n_streams);
        for si in 0..n_streams {
            let mut ts = rng.below(4);
            let n = rng.range(0, 40);
            let mut events = Vec::with_capacity(n);
            for i in 0..n {
                ts += rng.below(3); // zero increments force equal timestamps
                events.push(EventMsg {
                    ts,
                    rank: si as u32,
                    tid: i as u32,
                    hostname: hostname.clone(),
                    class: class.clone(),
                    fields: vec![],
                });
            }
            streams.push(events);
        }
        let parsed = ParsedTrace { metadata: Metadata::default(), streams };
        let expected: Vec<(u64, u32, u32)> =
            MessageSource::new(&parsed).map(|m| (m.ts, m.rank, m.tid)).collect();

        // hand-build the wire: Hello (empty metadata is fine — the tid/rank
        // carry the identity; class id 0 must resolve, so ship a one-class
        // table), then per-stream event runs with watermark beacons
        let mut md = String::from("btf_version: 1\nenv:\nevents:\n");
        md.push_str("  - id: 0\n    name: lttng_ust_ze:zeInit_entry\n    api: ZE\n    flags: h\n    fields:\n");
        let mut wire = Vec::new();
        thapi::remote::frame::write_preamble(&mut wire).unwrap();
        thapi::remote::frame::write_frame(
            &mut wire,
            &Frame::Hello {
                hostname: "remotenode".into(),
                metadata: md,
                streams: parsed.streams.len() as u32,
                epoch: 0,
            },
        )
        .unwrap();
        // interleave bounded runs from each stream, then close everything:
        // cursor[i] tracks how much of stream i is already on the wire
        let mut cursor = vec![0usize; parsed.streams.len()];
        loop {
            let mut progressed = false;
            for (i, s) in parsed.streams.iter().enumerate() {
                if cursor[i] >= s.len() {
                    continue;
                }
                progressed = true;
                let run = rng.range(1, 6).min(s.len() - cursor[i]);
                for m in &s[cursor[i]..cursor[i] + run] {
                    thapi::remote::frame::write_frame(
                        &mut wire,
                        &Frame::Event {
                            stream: i as u32,
                            event: WireEvent {
                                ts: m.ts,
                                rank: m.rank,
                                tid: m.tid,
                                class_id: 0,
                                fields: vec![],
                            },
                        },
                    )
                    .unwrap();
                }
                cursor[i] += run;
                if let Some(next) = s.get(cursor[i]) {
                    // valid watermark: this stream's future events start here
                    thapi::remote::frame::write_frame(
                        &mut wire,
                        &Frame::Beacon { stream: i as u32, watermark: next.ts },
                    )
                    .unwrap();
                }
            }
            if !progressed {
                break;
            }
        }
        for i in 0..parsed.streams.len() {
            thapi::remote::frame::write_frame(&mut wire, &Frame::Close { stream: i as u32 })
                .unwrap();
        }
        let total: u64 = parsed.streams.iter().map(|s| s.len() as u64).sum();
        thapi::remote::frame::write_frame(
            &mut wire,
            &Frame::Eos { received: total, dropped: 0 },
        )
        .unwrap();

        let att = Attachment::open(std::io::Cursor::new(wire), 8).unwrap();
        let got: Vec<(u64, u32, u32)> = att.source().map(|m| (m.ts, m.rank, m.tid)).collect();
        att.finish().unwrap();
        assert_eq!(got, expected, "remote merge must equal the post-mortem merge exactly");
    });
}
