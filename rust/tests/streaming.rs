//! Golden equivalence tests for the streaming analysis graph.
//!
//! The seed implementation materialized everything: an owned merged
//! `Vec<EventMsg>`, a second `Vec<Interval>`, and per-plugin rescans of
//! both. Those shims (`mux`, `pair_intervals`) are deleted; what remains
//! as an independent second implementation are the **eager renderers**
//! (`Tally::build`, `timeline_json`, `pretty_print`, `validate`), which
//! consume owned slices and share no pass with the sink graph. This
//! suite pins the streaming single-pass graph (lazy `MessageSource` →
//! incremental `IntervalTracker` → `AnalysisSink` fan-out) **byte for
//! byte** against those renderers on real traced workloads — the same
//! golden bar the shim suite used to set, now anchored on the streaming
//! primitives themselves.

use std::sync::{Mutex, MutexGuard};
use thapi::analysis::{
    self, AnalysisSink, EventMsg, MessageSource, PrettySink, TallySink, TimelineSink,
    ValidateSink,
};
use thapi::apps::{hecbench, spechpc};
use thapi::coordinator::{run, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::tracer::TracingMode;

/// Global-session tests cannot overlap.
static LOCK: Mutex<()> = Mutex::new(());
fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn app(name: &str) -> std::sync::Arc<dyn thapi::apps::Workload> {
    hecbench::suite()
        .into_iter()
        .chain(spechpc::suite())
        .find(|a| a.name() == name)
        .unwrap_or_else(|| panic!("app {name}"))
}

/// Trace one workload and return the parsed trace.
fn traced_on(name: &str, cfg: NodeConfig) -> analysis::ParsedTrace {
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = Node::new(cfg);
    let r = run(
        &node,
        app(name).as_ref(),
        &IprofConfig::paper_config(TracingMode::Default, false),
    );
    analysis::parse_trace(r.trace.as_ref().unwrap()).unwrap()
}

fn traced(name: &str) -> analysis::ParsedTrace {
    traced_on(name, NodeConfig::test_small())
}

/// The eager-renderer reference outputs: (tally, timeline, pretty,
/// validate) rendered from an owned merged vector + span vector —
/// deliberately NOT the sink path.
fn eager_reference(parsed: &analysis::ParsedTrace) -> (String, String, String, String) {
    let msgs: Vec<EventMsg> = MessageSource::new(parsed).cloned().collect();
    let intervals = analysis::intervals_of(parsed);
    (
        analysis::Tally::build(&intervals, &msgs).render(),
        analysis::timeline_json(&intervals, &msgs),
        analysis::pretty_print(&msgs),
        analysis::validate::render_report(&analysis::validate(&msgs)),
    )
}

/// The streaming single-pass outputs in the same order.
fn single_pass(parsed: &analysis::ParsedTrace) -> (String, String, String, String) {
    let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![
        Box::new(TallySink::new()),
        Box::new(TimelineSink::new()),
        Box::new(PrettySink::new()),
        Box::new(ValidateSink::new()),
    ];
    let reports = analysis::run_pipeline(parsed, &mut sinks);
    let mut texts: Vec<String> =
        reports.iter().map(|r| r.payload().unwrap_or("").to_string()).collect();
    let validate = texts.pop().unwrap();
    let pretty = texts.pop().unwrap();
    let timeline = texts.pop().unwrap();
    let tally = texts.pop().unwrap();
    (tally, timeline, pretty, validate)
}

#[test]
fn streaming_graph_is_byte_identical_on_hiplz_app() {
    let _g = lock();
    // lrn-hip layers HIP on ZE: nested intervals, device rows, kernels
    let parsed = traced("lrn-hip");
    assert!(parsed.event_count() > 100);
    let (t2, j2, p2, v2) = eager_reference(&parsed);
    let (t1, j1, p1, v1) = single_pass(&parsed);
    assert_eq!(t1, t2, "tally must match byte-for-byte");
    assert_eq!(j1, j2, "timeline must match byte-for-byte");
    assert_eq!(p1, p2, "pretty print must match byte-for-byte");
    assert_eq!(v1, v2, "validation report must match byte-for-byte");
}

#[test]
fn streaming_graph_is_byte_identical_on_mpi_offload_app() {
    let _g = lock();
    // multi-rank MPI + OpenMP offload on a multi-GPU node: many streams
    // through the muxer
    let parsed = traced_on("513.soma", NodeConfig::polaris());
    assert!(parsed.streams.len() > 1, "need a multi-stream trace");
    let (t2, j2, p2, v2) = eager_reference(&parsed);
    let (t1, j1, p1, v1) = single_pass(&parsed);
    assert_eq!(t1, t2);
    assert_eq!(j1, j2);
    assert_eq!(p1, p2);
    assert_eq!(v1, v2);
}

#[test]
fn one_pass_drives_multiple_sinks_like_iprof_a_tally_timeline() {
    let _g = lock();
    // the `iprof -a tally,timeline` shape: two sinks, one pass, both
    // outputs equal to their dedicated-run counterparts
    let parsed = traced("saxpy-ze");
    let mut both: Vec<Box<dyn AnalysisSink>> =
        vec![Box::new(TallySink::new()), Box::new(TimelineSink::new())];
    let reports = analysis::run_pipeline(&parsed, &mut both);
    assert_eq!(reports.len(), 2);

    let mut only_tally: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let mut only_timeline: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TimelineSink::new())];
    let rt = analysis::run_pipeline(&parsed, &mut only_tally);
    let rj = analysis::run_pipeline(&parsed, &mut only_timeline);
    assert_eq!(reports[0].payload(), rt[0].payload());
    assert_eq!(reports[1].payload(), rj[0].payload());
    assert!(reports[0].payload().unwrap().contains("Time(%)"));
    assert!(reports[1].payload().unwrap().contains("traceEvents"));
}

#[test]
fn streaming_tally_matches_runreport_tally() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = Node::new(NodeConfig::test_small());
    let r = run(&node, app("saxpy-ze").as_ref(), &IprofConfig::default());
    let tally = r.tally().unwrap();
    let parsed = analysis::parse_trace(r.trace.as_ref().unwrap()).unwrap();
    let msgs: Vec<EventMsg> = MessageSource::new(&parsed).cloned().collect();
    let eager = analysis::Tally::build(&analysis::intervals_of(&parsed), &msgs);
    assert_eq!(tally.host, eager.host);
    assert_eq!(tally.device, eager.device);
    assert_eq!(tally.render(), eager.render());
}

#[test]
fn lazy_merge_is_reproducible_and_ordered() {
    let _g = lock();
    // deleting the owned-vector shims must not lose the ordering contract:
    // two lazy passes agree element-for-element and are time-ordered with
    // the (ts, stream, in-stream) tie-break
    let parsed = traced_on("513.soma", NodeConfig::polaris());
    let a: Vec<(u64, u32, u32)> =
        MessageSource::new(&parsed).map(|m| (m.ts, m.rank, m.tid)).collect();
    let b: Vec<(u64, u32, u32)> =
        MessageSource::new(&parsed).map(|m| (m.ts, m.rank, m.tid)).collect();
    assert_eq!(a, b, "the merge is a pure function of the parsed trace");
    assert!(a.windows(2).all(|w| w[0].0 <= w[1].0), "non-decreasing timestamps");
    assert_eq!(a.len(), parsed.event_count());
}
