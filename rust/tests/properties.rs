//! Property-based tests (in-crate `util::prop` harness) over the
//! tracer/model/analysis invariants.

use thapi::model::{ApiModel, CType, FnModel, Param};
use thapi::tracer::ringbuf::{parse_record, RingBuf};
use thapi::util::{prop, Rng};

// ---------------------------------------------------------------------------
// Ring buffer
// ---------------------------------------------------------------------------

/// Whatever the interleaving of writes and drains, every record drained
/// parses back exactly as written, in order, with written+dropped == sent.
#[test]
fn prop_ringbuf_preserves_order_and_content() {
    prop::check(50, 0x1234, |rng| {
        let cap = 1usize << rng.range(12, 16);
        let rb = RingBuf::new(cap);
        let rounds = rng.range(1, 60);
        let mut expect: std::collections::VecDeque<(u32, u64, Vec<u8>)> = Default::default();
        let mut sent = 0u64;
        for round in 0..rounds {
            let burst = rng.range(1, 50);
            for i in 0..burst {
                let len = rng.range(0, 200);
                let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
                let id = (round * 1000 + i) as u32;
                sent += 1;
                if rb.try_write(id, sent, &payload) {
                    expect.push_back((id, sent, payload));
                }
            }
            if rng.chance(0.7) {
                rb.drain(|rec| {
                    let (id, ts, payload) = parse_record(rec);
                    let (eid, ets, epayload) =
                        expect.pop_front().expect("drained more than written");
                    assert_eq!(id, eid);
                    assert_eq!(ts, ets);
                    assert_eq!(&payload[..epayload.len()], &epayload[..]);
                });
            }
        }
        rb.drain(|rec| {
            let (id, _, _) = parse_record(rec);
            let (eid, _, _) = expect.pop_front().expect("drained more than written");
            assert_eq!(id, eid);
        });
        assert!(expect.is_empty(), "all surviving records must drain");
        assert_eq!(rb.written() + rb.dropped(), sent);
    });
}

/// Free space is fully reusable: after draining, a buffer accepts new
/// records of any admissible size again (no fragmentation leak).
#[test]
fn prop_ringbuf_space_is_reusable() {
    prop::check(30, 99, |rng| {
        let rb = RingBuf::new(4096);
        for _ in 0..rng.range(50, 400) {
            let len = rng.range(0, 900);
            let payload = vec![0u8; len];
            if !rb.try_write(1, 1, &payload) {
                // full: drain everything, then the same record must fit
                rb.drain(|_| {});
                assert!(
                    rb.try_write(1, 1, &payload),
                    "record of {len}B must fit into an empty 4096B ring"
                );
            }
            if rng.chance(0.2) {
                rb.drain(|_| {});
            }
        }
    });
}

// ---------------------------------------------------------------------------
// YAML API-model interchange
// ---------------------------------------------------------------------------

fn random_ctype(rng: &mut Rng, depth: u32) -> CType {
    match rng.below(if depth > 2 { 6 } else { 7 }) {
        0 => CType::Int { bits: 32, name: "int32_t".into() },
        1 => CType::Uint { bits: 64, name: "size_t".into() },
        2 => CType::Float { bits: 64, name: "double".into() },
        3 => CType::Handle { name: format!("h{}_t", rng.below(20)) },
        4 => CType::Enum { name: format!("e{}_t", rng.below(20)) },
        5 => CType::CString,
        _ => CType::Ptr {
            inner: Box::new(random_ctype(rng, depth + 1)),
            is_const: rng.chance(0.5),
        },
    }
}

/// Any API model survives the YAML emit→parse round trip.
#[test]
fn prop_yaml_api_model_roundtrip() {
    prop::check(60, 0xabc, |rng| {
        let n_fns = rng.range(1, 12);
        let mut model = ApiModel::default();
        for i in 0..n_fns {
            let n_params = rng.range(0, 8);
            model.functions.push(FnModel {
                name: format!("fn{i}"),
                ret: random_ctype(rng, 0),
                params: (0..n_params)
                    .map(|j| Param { name: format!("p{j}"), ty: random_ctype(rng, 0) })
                    .collect(),
            });
        }
        let n_enums = rng.range(0, 4);
        for i in 0..n_enums {
            let vals = (0..rng.range(1, 6))
                .map(|j| (format!("V{j}"), rng.below(1000) as i64 - 500))
                .collect();
            model.enums.push((format!("enum{i}_t"), vals));
        }
        let text = thapi::model::yaml::emit_api_model(&model);
        let back = thapi::model::yaml::parse_api_model(&text)
            .unwrap_or_else(|e| panic!("parse failed: {e:#}\n{text}"));
        assert_eq!(model.functions, back.functions);
        assert_eq!(model.enums, back.enums);
    });
}

// ---------------------------------------------------------------------------
// Tally merge algebra
// ---------------------------------------------------------------------------

fn random_tally(rng: &mut Rng) -> thapi::analysis::Tally {
    use thapi::analysis::TallyRow;
    let mut t = thapi::analysis::Tally::default();
    let apis = ["ZE", "CUDA", "HIP"];
    for _ in 0..rng.range(1, 10) {
        let api = apis[rng.range(0, apis.len())].to_string();
        let name = format!("fn{}", rng.below(6));
        let calls = 1 + rng.below(1000);
        let avg = 1 + rng.below(100_000);
        let row = TallyRow {
            name: name.clone(),
            api: api.clone(),
            time_ns: calls * avg,
            calls,
            min_ns: avg / 2 + 1,
            max_ns: avg * 2,
        };
        match t.host.get_mut(&(api.clone(), name.clone())) {
            Some(r) => {
                r.time_ns += row.time_ns;
                r.calls += row.calls;
            }
            None => {
                t.host.insert((api, name), row);
            }
        }
    }
    t.processes.insert(rng.below(64) as u32);
    t
}

/// Merge is commutative and associative on (time, calls) and
/// min/max-correct.
#[test]
fn prop_tally_merge_is_commutative_and_associative() {
    prop::check(60, 7, |rng| {
        let a = random_tally(rng);
        let b = random_tally(rng);
        let c = random_tally(rng);

        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.host, ba.host, "merge must be commutative");

        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c.host, a_bc.host, "merge must be associative");

        for (k, r) in &ab.host {
            let ta = a.host.get(k).map(|r| r.time_ns).unwrap_or(0);
            let tb = b.host.get(k).map(|r| r.time_ns).unwrap_or(0);
            assert_eq!(r.time_ns, ta + tb);
            assert!(r.min_ns <= r.max_ns);
        }
    });
}

/// serialize ∘ deserialize = identity.
#[test]
fn prop_tally_serialization_roundtrip() {
    prop::check(60, 21, |rng| {
        let t = random_tally(rng);
        let s = t.serialize();
        let back = thapi::analysis::Tally::deserialize(&s).unwrap();
        assert_eq!(t.host, back.host);
        assert_eq!(t.processes, back.processes);
    });
}

// ---------------------------------------------------------------------------
// Streaming muxer
// ---------------------------------------------------------------------------

/// A synthetic multi-stream parsed trace: each stream non-decreasing in
/// time (as `parse_trace` produces), with deliberate cross-stream and
/// in-stream timestamp ties. Stream index is encoded in `rank` and the
/// in-stream position in `tid` so the merge order is fully observable.
fn synthetic_parsed(rng: &mut Rng) -> thapi::analysis::ParsedTrace {
    use std::sync::Arc;
    use thapi::analysis::EventMsg;
    use thapi::tracer::btf::{DecodedClass, Metadata};
    let class = Arc::new(DecodedClass {
        id: 0,
        name: "lttng_ust_ze:zeInit_entry".to_string(),
        api: "ZE".to_string(),
        flags: "h".to_string(),
        fields: vec![],
    });
    let hostname: Arc<str> = Arc::from("propnode");
    let n_streams = rng.range(1, 8);
    let mut streams = Vec::with_capacity(n_streams);
    for si in 0..n_streams {
        let mut ts = rng.below(4);
        let n = rng.range(0, 60);
        let mut events = Vec::with_capacity(n);
        for i in 0..n {
            ts += rng.below(3); // 0 increments force equal timestamps
            events.push(EventMsg {
                ts,
                rank: si as u32,
                tid: i as u32,
                hostname: hostname.clone(),
                class: class.clone(),
                fields: vec![],
            });
        }
        streams.push(events);
    }
    thapi::analysis::ParsedTrace { metadata: Metadata::default(), streams }
}

/// The streaming muxer preserves global time order and stream-index
/// stability: its output is exactly the stable sort of all events by
/// (ts, stream index, in-stream index), i.e. ties break by stream and
/// per-stream order is never reordered. (The live and remote merges are
/// pinned to this same order by `rust/tests/live.rs` and
/// `rust/tests/remote.rs`.)
#[test]
fn prop_streaming_muxer_time_order_and_stream_stability() {
    use thapi::analysis::MessageSource;
    prop::check(60, 0x5eed, |rng| {
        let parsed = synthetic_parsed(rng);
        let total: usize = parsed.streams.iter().map(|s| s.len()).sum();

        // reference: stable global order per the muxer contract
        let mut expected: Vec<(u64, u32, u32)> = parsed
            .streams
            .iter()
            .flat_map(|s| s.iter().map(|m| (m.ts, m.rank, m.tid)))
            .collect();
        expected.sort_by_key(|&(ts, stream, idx)| (ts, stream, idx));

        let merged: Vec<(u64, u32, u32)> =
            MessageSource::new(&parsed).map(|m| (m.ts, m.rank, m.tid)).collect();
        assert_eq!(merged.len(), total);
        assert_eq!(merged, expected, "lazy merge must be the stable (ts, stream) order");

        // global time order + per-stream stability, stated directly
        for w in merged.windows(2) {
            assert!(w[0].0 <= w[1].0, "time order violated: {w:?}");
            if w[0].0 == w[1].0 {
                assert!(
                    (w[0].1, w[0].2) < (w[1].1, w[1].2),
                    "tie must break by (stream, index): {w:?}"
                );
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Encoder/decoder
// ---------------------------------------------------------------------------

/// Random payloads round-trip through encode/decode for random field
/// layouts.
#[test]
fn prop_encoder_decoder_roundtrip() {
    use thapi::model::{EventClass, FieldDef, FieldType};
    use thapi::tracer::encoder::{decode_payload, Encoder, FieldValue};
    prop::check(80, 5, |rng| {
        let types = [
            FieldType::U32,
            FieldType::U64,
            FieldType::I64,
            FieldType::F64,
            FieldType::Ptr,
            FieldType::Str,
        ];
        let n = rng.range(0, 10);
        let fields: Vec<FieldDef> = (0..n)
            .map(|i| FieldDef::new(format!("f{i}"), types[rng.range(0, types.len())]))
            .collect();
        let class = EventClass::new_for_test("p:q_entry", fields.clone());
        let mut values = Vec::new();
        let mut buf = Vec::new();
        let mut enc = Encoder::new(&mut buf, &class);
        for f in &fields {
            match f.ty {
                FieldType::U32 => {
                    let v = rng.below(u32::MAX as u64 + 1) as u32;
                    enc.u32(v);
                    values.push(FieldValue::U64(v as u64));
                }
                FieldType::U64 => {
                    let v = rng.next_u64();
                    enc.u64(v);
                    values.push(FieldValue::U64(v));
                }
                FieldType::I64 => {
                    let v = rng.next_u64() as i64;
                    enc.i64(v);
                    values.push(FieldValue::I64(v));
                }
                FieldType::F64 => {
                    let v = rng.f64() * 1e6 - 5e5;
                    enc.f64(v);
                    values.push(FieldValue::F64(v));
                }
                FieldType::Ptr => {
                    let v = rng.next_u64();
                    enc.ptr(v);
                    values.push(FieldValue::Ptr(v));
                }
                FieldType::Str => {
                    let len = rng.range(0, 64);
                    let s: String =
                        (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
                    enc.str(&s);
                    values.push(FieldValue::Str(s));
                }
            }
        }
        enc.finish();
        let decoded = decode_payload(&fields, &buf);
        assert_eq!(decoded, values);
    });
}
