//! Integration tests: whole-stack behaviour across modules.
//!
//! Each test drives real workloads through the traced frontends on a
//! simulated node (with real PJRT kernel execution) and checks the
//! resulting traces through the analysis pipeline. Requires artifacts
//! (`make artifacts`).

use std::sync::{Mutex, MutexGuard};
use std::time::Duration;
use thapi::analysis;
use thapi::apps::{hecbench, spechpc};
use thapi::coordinator::{run, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::sampling::SamplingConfig;
use thapi::tracer::{btf, SinkKind, TracingMode};

/// Global-session tests cannot overlap.
static LOCK: Mutex<()> = Mutex::new(());
fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn small_node() -> std::sync::Arc<Node> {
    Node::new(NodeConfig::test_small())
}

fn app(name: &str) -> std::sync::Arc<dyn thapi::apps::Workload> {
    hecbench::suite()
        .into_iter()
        .chain(spechpc::suite())
        .find(|a| a.name() == name)
        .unwrap_or_else(|| panic!("app {name}"))
}

#[test]
fn traced_run_roundtrips_through_disk() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = small_node();
    let dir = std::env::temp_dir().join(format!("thapi_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let config = IprofConfig { sink: SinkKind::Dir(dir.clone()), ..Default::default() };
    let report = run(&node, app("saxpy-ze").as_ref(), &config);
    assert!(report.trace_bytes() > 0);

    // reload from disk and compare event counts
    let reloaded = btf::read_dir(&dir).unwrap();
    assert_eq!(reloaded.record_count(), report.trace.as_ref().unwrap().record_count());
    let parsed = analysis::parse_trace(&reloaded).unwrap();
    assert!(parsed.event_count() > 0);
    // the zero-copy merge yields every event in global time order
    let mut merged = 0usize;
    let mut prev = 0u64;
    for m in analysis::MessageSource::new(&parsed) {
        assert!(m.ts >= prev);
        prev = m.ts;
        merged += 1;
    }
    assert_eq!(merged, parsed.event_count());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn mode_event_counts_are_ordered_min_default_full() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = small_node();
    let a = app("eventspin-ze");
    let mut sizes = Vec::new();
    let mut counts = Vec::new();
    for mode in [TracingMode::Minimal, TracingMode::Default, TracingMode::Full] {
        let r = run(&node, a.as_ref(), &IprofConfig::paper_config(mode, false));
        sizes.push(r.trace_bytes());
        counts.push(r.stats.unwrap().written);
    }
    // The spin-loop iteration count varies run to run, so default-vs-full
    // totals are not strictly ordered across *separate* runs; minimal
    // mode's count, however, is structurally far below both.
    assert!(
        counts[0] * 10 < counts[1] && counts[0] * 10 < counts[2],
        "minimal must track far fewer events: {counts:?}"
    );
    assert!(
        sizes[0] * 3 < sizes[1].min(sizes[2]),
        "minimal trace must be far smaller: {sizes:?}"
    );
}

#[test]
fn polling_app_separates_default_from_full() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = small_node();
    let a = app("queryspin-cuda");
    let d = run(&node, a.as_ref(), &IprofConfig::paper_config(TracingMode::Default, false));
    let f = run(&node, a.as_ref(), &IprofConfig::paper_config(TracingMode::Full, false));
    let dc = d.stats.unwrap().written;
    let fc = f.stats.unwrap().written;
    assert!(
        fc > dc * 2,
        "cuEventQuery storms must appear only in full mode (default {dc}, full {fc})"
    );
}

#[test]
fn sampling_adds_telemetry_events() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.15");
    let node = small_node();
    let a = app("jacobi2D-ze");
    let mut config = IprofConfig::paper_config(TracingMode::Default, true);
    config.sampling = Some(SamplingConfig { interval: Duration::from_millis(5) });
    let r = run(&node, a.as_ref(), &config);
    let trace = r.trace.as_ref().unwrap();
    let parsed = analysis::parse_trace(trace).unwrap();
    let telemetry = analysis::MessageSource::new(&parsed)
        .filter(|m| m.class.name.starts_with("lttng_ust_sampling"))
        .count();
    assert!(telemetry > 10, "expected telemetry events, got {telemetry}");
    // power domains present: card + 2 tiles
    let domains: std::collections::HashSet<u64> = analysis::MessageSource::new(&parsed)
        .filter(|m| m.class.name == "lttng_ust_sampling:gpu_power")
        .map(|m| m.field("domain").unwrap().as_u64())
        .collect();
    assert_eq!(domains, [0u64, 1, 2].into_iter().collect());
}

#[test]
fn tally_of_hiplz_app_shows_layering_shape() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.2");
    let node = small_node();
    let r = run(&node, app("lrn-hip").as_ref(), &IprofConfig::default());
    let tally = r.tally().unwrap();
    let rows = tally.host_rows();
    let calls = |n: &str| rows.iter().find(|r| r.name == n).map(|r| r.calls).unwrap_or(0);
    // the §4.3 shape: spin calls dominate call counts
    assert!(calls("zeEventHostSynchronize") > calls("hipDeviceSynchronize"));
    assert!(calls("hipLaunchKernel") > 0);
    // device rows exist and carry the kernel name
    assert!(tally.device.contains_key("lrn"), "device tally rows: {:?}", tally.device.keys());
    // backend header counts both HIP and ZE
    let bc = tally.backend_counts();
    assert!(bc.contains_key("HIP") && bc.contains_key("ZE"));
}

#[test]
fn spechpc_app_runs_traced_on_aurora_and_polaris() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    for cfg in [NodeConfig::aurora(), NodeConfig::polaris()] {
        let gpus = cfg.gpu_count;
        let node = Node::new(cfg);
        let r = run(&node, app("519.clvleaf").as_ref(), &IprofConfig::default());
        let tally = r.tally().unwrap();
        assert_eq!(
            tally.processes.len() as u32,
            gpus,
            "one MPI rank per GPU must appear in the tally"
        );
        assert!(tally.backend_counts().contains_key("MPI"));
        assert!(tally.backend_counts().contains_key("OMP"));
    }
}

#[test]
fn rank_selection_restricts_trace() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = Node::new(NodeConfig { gpu_count: 2, ..NodeConfig::test_small() });
    let mut config = IprofConfig::default();
    config.selected_ranks = Some([1u32].into_iter().collect());
    let r = run(&node, app("505.lbm").as_ref(), &config);
    let tally = r.tally().unwrap();
    // only rank 1's thread streams exist (engine/sampler threads are rank 0
    // but emit only profiling events, attributed to rank 0 streams if any)
    assert!(tally.processes.contains(&1));
    assert!(
        !tally.host.keys().any(|(api, _)| api == "MPI") || !tally.processes.contains(&0),
        "rank 0 host API calls must be filtered out"
    );
}

#[test]
fn event_filter_disables_matching_classes() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = small_node();
    let mut config = IprofConfig::default();
    config.disabled_patterns = vec!["zeKernelSetArgumentValue".into()];
    let r = run(&node, app("saxpy-ze").as_ref(), &config);
    let trace = r.trace.as_ref().unwrap();
    let parsed = analysis::parse_trace(trace).unwrap();
    assert!(
        !analysis::MessageSource::new(&parsed)
            .any(|m| m.class.name.contains("zeKernelSetArgumentValue")),
        "filtered class must not appear"
    );
    assert!(analysis::MessageSource::new(&parsed)
        .any(|m| m.class.name.contains("zeCommandListAppendLaunchKernel")));
}

#[test]
fn pretty_print_covers_all_recorded_classes() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = small_node();
    let r = run(&node, app("miniweather-ze").as_ref(), &IprofConfig::default());
    let trace = r.trace.as_ref().unwrap();
    let parsed = analysis::parse_trace(trace).unwrap();
    let mut sinks: Vec<Box<dyn analysis::AnalysisSink>> =
        vec![Box::new(analysis::PrettySink::new())];
    let reports = analysis::run_pipeline(&parsed, &mut sinks);
    let text = reports[0].payload().unwrap();
    assert_eq!(text.lines().count(), parsed.event_count());
    // every line carries the hostname and a field block
    for line in text.lines().take(50) {
        assert!(line.contains("testnode"));
        assert!(line.contains('{'));
    }
}

#[test]
fn timeline_json_from_sampled_run_is_valid_shape() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = small_node();
    let mut config = IprofConfig::paper_config(TracingMode::Default, true);
    config.sampling = Some(SamplingConfig { interval: Duration::from_millis(5) });
    let r = run(&node, app("convolution1D-ze").as_ref(), &config);
    let trace = r.trace.as_ref().unwrap();
    let parsed = analysis::parse_trace(trace).unwrap();
    let mut sinks: Vec<Box<dyn analysis::AnalysisSink>> =
        vec![Box::new(analysis::TimelineSink::new())];
    let reports = analysis::run_pipeline(&parsed, &mut sinks);
    let json = reports[0].payload().unwrap();
    assert!(json.contains("traceEvents"));
    assert_eq!(json.matches('{').count(), json.matches('}').count());
    assert!(json.contains("GPU Power Domain 0"));
}

#[test]
fn clean_apps_pass_validation() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = small_node();
    for name in ["saxpy-ze", "gemm-cuda", "saxpy-cl"] {
        let r = run(&node, app(name).as_ref(), &IprofConfig::default());
        let trace = r.trace.as_ref().unwrap();
        let parsed = analysis::parse_trace(trace).unwrap();
        let mut validator = analysis::Validator::new();
        for m in analysis::MessageSource::new(&parsed) {
            validator.observe(m);
        }
        let findings = validator.finish();
        let errors: Vec<_> =
            findings.iter().filter(|f| f.severity == analysis::Severity::Error).collect();
        assert!(errors.is_empty(), "{name} must validate clean, got {errors:?}");
    }
}

#[test]
fn aggregate_only_flow_from_real_traces() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = small_node();
    let mut per_rank = Vec::new();
    for node_id in 0..3u32 {
        let r = run(&node, app("513.soma").as_ref(), &IprofConfig::default());
        let tally = r.tally().unwrap();
        per_rank.push((node_id, 0u32, tally));
    }
    let (composite, bytes) = thapi::aggregate::aggregate_tree(&per_rank).unwrap();
    let soma_calls: u64 = composite
        .host
        .values()
        .filter(|r| r.name == "MPI_Allreduce")
        .map(|r| r.calls)
        .sum();
    let single_calls: u64 = per_rank[0]
        .2
        .host
        .values()
        .filter(|r| r.name == "MPI_Allreduce")
        .map(|r| r.calls)
        .sum();
    assert_eq!(soma_calls, single_calls * 3);
    assert!(bytes > 0);
}
