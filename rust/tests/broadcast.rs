//! Broadcast serve tests (`iprof serve --subscribers N`).
//!
//! One [`Broadcaster`] session, N concurrent subscribers over one
//! shared replay ring. The acceptance bar: every subscriber that keeps
//! up merges byte-identically to a solo subscriber of the same session
//! (mixed v2/v3 wires, late joiners included); ring eviction never
//! strands an *entitled* cursor (randomized join/kill property); a
//! laggard over its `--max-lag` budget is demoted to gap delivery with
//! an exact [`Frame::ResumeGap`] — and none of it perturbs anyone
//! else's byte stream or ledgers (fault injection). On the wire each
//! connection is an independent, fully conforming resumable THRL
//! connection — broadcast is server-side, invisible to subscribers.

use std::io::{self, Cursor, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};
use thapi::analysis::EventMsg;
use thapi::live::LiveHub;
use thapi::remote::{
    decode, encode, publish_with, Broadcaster, FanIn, FanInStats, Frame, KillAfter,
    ReconnectPolicy, ServeOutcome, WireEvent,
};
use thapi::tracer::btf::generate_metadata;
use thapi::tracer::encoder::FieldValue;
use thapi::util::prop;

/// Decode a registry-class message through `hub` (so the class id
/// resolves on the attach side exactly like a real consumer's would).
fn reg_msg(hub: &LiveHub, name: &str, ts: u64, rank: u32, tid: u32) -> EventMsg {
    let class = thapi::model::class_by_name(name).unwrap();
    hub.decode(rank, tid, class.id, ts, &0u64.to_le_bytes()).unwrap()
}

/// Push `events` onto `stream`, alternating entry/exit classes by the
/// event's position in the WHOLE stream (`offset` + local index) — so a
/// phased push produces the exact same content as one-shot fill.
fn push_events(hub: &LiveHub, stream: usize, events: &[(u64, u32, u32)], offset: usize) {
    let msgs: Vec<EventMsg> = events
        .iter()
        .enumerate()
        .map(|(j, &(ts, rank, tid))| {
            let name = if (offset + j) % 2 == 0 {
                "lttng_ust_ze:zeInit_entry"
            } else {
                "lttng_ust_ze:zeInit_exit"
            };
            reg_msg(hub, name, ts, rank, tid)
        })
        .collect();
    hub.push_batch(stream, msgs);
}

/// The merged `(ts, rank, tid)` sequence a SOLO subscriber of exactly
/// this stream set sees — the baseline every broadcast subscriber must
/// match.
fn solo_expected(hostname: &str, batches: &[Vec<(u64, u32, u32)>]) -> Vec<(u64, u32, u32)> {
    let hub = LiveHub::new(hostname, 64, false);
    hub.ensure_channels(batches.len());
    for (i, b) in batches.iter().enumerate() {
        push_events(&hub, i, b, 0);
    }
    hub.close_all();
    let mut buf = Vec::new();
    publish_with(&hub, &mut buf, 2).unwrap();
    let fan = FanIn::open(vec![Cursor::new(buf)], 64).unwrap();
    let merged: Vec<(u64, u32, u32)> = fan.source().map(|m| (m.ts, m.rank, m.tid)).collect();
    fan.finish().unwrap();
    merged
}

/// Wire size of one per-event v2 `Event` frame for our registry
/// payloads — the ring's budget unit.
fn event_len() -> usize {
    let mut buf = Vec::new();
    encode(
        &Frame::Event {
            stream: 0,
            event: WireEvent {
                ts: 10,
                rank: 0,
                tid: 1,
                class_id: thapi::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap().id,
                fields: vec![FieldValue::U64(0)],
            },
        },
        &mut buf,
    );
    buf.len()
}

/// Wire size of the Hello a broadcast publisher sends — lets a test aim
/// a kill budget past the handshake and into the event stream.
fn hello_wire_len(hostname: &str, streams: u32, epoch: u64) -> usize {
    let mut buf = Vec::new();
    encode(
        &Frame::Hello {
            hostname: hostname.into(),
            metadata: generate_metadata(&[]),
            streams,
            epoch,
        },
        &mut buf,
    );
    buf.len()
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(20);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Run one full subscriber over an established connection: handshake
/// (Resume included — the broadcast epoch is nonzero), merge to the
/// end, report the merged tuples plus connection stats. `None` when the
/// connection died during the handshake (a killed subscriber).
fn attach_client(stream: TcpStream) -> Option<(Vec<(u64, u32, u32)>, FanInStats)> {
    let mut slot = Some(stream);
    let connector = move || {
        slot.take()
            .ok_or_else(|| io::Error::new(io::ErrorKind::ConnectionRefused, "single-use conn"))
    };
    let fan = FanIn::open_resumable(vec![connector], 64, ReconnectPolicy::none()).ok()?;
    let merged: Vec<(u64, u32, u32)> = fan.source().map(|m| (m.ts, m.rank, m.tid)).collect();
    let stats = fan.finish().ok()?;
    Some((merged, stats))
}

// ---------------------------------------------------------------------------
// Golden: three concurrent subscribers on mixed wires (v3, v2, v3 —
// the third attaching late via Resume) each merge byte-identically to
// a solo subscriber of the same session
// ---------------------------------------------------------------------------

#[test]
fn three_mixed_wire_subscribers_merge_identically_to_solo_baseline() {
    // two streams with tied timestamps across them, split into a phase
    // pushed before anyone connects and a phase pushed live
    let batches: Vec<Vec<(u64, u32, u32)>> = vec![
        vec![(10, 0, 1), (15, 0, 1), (20, 0, 1), (25, 0, 1), (30, 0, 1)],
        vec![(10, 0, 2), (16, 0, 2), (21, 0, 2), (26, 0, 2), (31, 0, 2)],
    ];
    let splits = [3usize, 2usize];
    let phase1: u64 = splits.iter().map(|&s| s as u64).sum();
    let total: u64 = batches.iter().map(|b| b.len() as u64).sum();
    let expected = solo_expected("bchost", &batches);
    assert_eq!(expected.len() as u64, total);

    let hub = LiveHub::new("bchost", 64, false);
    hub.ensure_channels(batches.len());
    for (i, b) in batches.iter().enumerate() {
        push_events(&hub, i, &b[..splits[i]], 0);
    }
    let bc = Broadcaster::new(hub.clone(), 0xBCA57, 64 << 20);
    bc.drain_to_ring();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let wires = [3u32, 2, 3];

    let results: Vec<Option<(Vec<(u64, u32, u32)>, FanInStats)>> = std::thread::scope(|s| {
        let bc = &bc;
        s.spawn(move || {
            for wire in wires {
                let (conn, _) = listener.accept().unwrap();
                s.spawn(move || bc.serve_connection(conn, wire));
            }
        });

        // subscribers 0 (v3) and 1 (v2) join before the live phase;
        // sequential connects + a registration poll pin the row order
        let c0 = TcpStream::connect(addr).unwrap();
        wait_until("subscriber 0 registered", || bc.subscriber_stats().len() >= 1);
        let c1 = TcpStream::connect(addr).unwrap();
        wait_until("subscriber 1 registered", || bc.subscriber_stats().len() >= 2);
        let h0 = s.spawn(move || attach_client(c0));
        let h1 = s.spawn(move || attach_client(c1));
        wait_until("both live subscribers consumed phase 1", || {
            bc.subscriber_stats().iter().take(2).all(|r| r.forwarded == phase1)
        });

        // live phase, then end of session
        for (i, b) in batches.iter().enumerate() {
            push_events(&hub, i, &b[splits[i]..], splits[i]);
        }
        hub.close_all();
        bc.pump();

        // subscriber 2 attaches AFTER the session finished: pure ring
        // replay via its Resume — the late-joiner path
        let c2 = TcpStream::connect(addr).unwrap();
        wait_until("subscriber 2 registered", || bc.subscriber_stats().len() >= 3);
        let h2 = s.spawn(move || attach_client(c2));

        vec![h0.join().unwrap(), h1.join().unwrap(), h2.join().unwrap()]
    });

    for (i, r) in results.iter().enumerate() {
        let (merged, stats) = r.as_ref().unwrap_or_else(|| panic!("subscriber {i} died"));
        assert_eq!(
            merged, &expected,
            "subscriber {i} must merge identically to a solo subscriber"
        );
        assert_eq!(stats.per[0].wire_version, wires[i], "negotiation is per-connection");
        assert!(stats.per[0].error.is_none(), "{:?}", stats.per[0]);
        assert_eq!(stats.per[0].resume_gap, 0);
        assert_eq!(stats.per[0].server_dropped, 0);
    }
    // v3 live rounds are batched; a replay round is always per-event
    // (the frozen stream-replay grammar), so the late v3 joiner — who
    // only ever sees replay — gets zero batches
    assert!(results[0].as_ref().unwrap().1.per[0].batches >= 1, "v3 live rounds batch");
    assert_eq!(results[1].as_ref().unwrap().1.per[0].batches, 0, "v2 never batches");
    assert_eq!(results[2].as_ref().unwrap().1.per[0].batches, 0, "replay is per-event");

    let rows = bc.subscriber_stats();
    assert_eq!(rows.len(), 3);
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.id, i);
        assert_eq!(row.wire, wires[i]);
        assert_eq!(row.forwarded, total, "{row:?}");
        assert_eq!(row.lagged, 0, "{row:?}");
        assert_eq!(row.demoted, 0, "{row:?}");
        assert_eq!(row.disconnects, 0, "{row:?}");
        assert!(row.error.is_none(), "{row:?}");
    }
    let agg = bc.stats();
    assert_eq!(agg.connections, 3);
    assert_eq!(agg.events, 3 * total, "aggregate counts every subscriber's delivery");
    assert_eq!(agg.gaps, 0);
}

// ---------------------------------------------------------------------------
// Property: randomized join/kill schedules over random stream sets and
// ring budgets — the observable form of the ring invariants: an entry
// is only evicted when every entitled cursor consumed it (roomy ring ⇒
// zero lag for everyone), and every lagged event is booked as an exact
// ResumeGap on BOTH ends (server row == subscriber ledger)
// ---------------------------------------------------------------------------

#[test]
fn random_join_and_kill_schedules_preserve_ring_invariants() {
    let ev_len = event_len();
    prop::check(6, 0xb40adca5, |rng| {
        let n_streams = rng.range(1, 3);
        let mut batches: Vec<Vec<(u64, u32, u32)>> = Vec::new();
        for s in 0..n_streams {
            let n = rng.range(0, 14);
            let mut ts = rng.below(4);
            let mut evs = Vec::new();
            for _ in 0..n {
                evs.push((ts, 0u32, (s + 1) as u32));
                ts += rng.below(3); // zero increments force equal timestamps
            }
            batches.push(evs);
        }
        let total: u64 = batches.iter().map(|b| b.len() as u64).sum();
        let splits: Vec<usize> =
            batches.iter().map(|b| if b.is_empty() { 0 } else { rng.range(0, b.len() + 1) }).collect();
        let expected = solo_expected("bchost", &batches);

        // roomy ring: nothing may ever lag (the entitlement invariant);
        // tight ring: phase-0 events evicted before a late join must
        // come back as an EXACT gap, never silently
        let roomy = rng.chance(0.5);
        let budget = if roomy { 64 << 20 } else { ev_len * rng.range(2, 8) };

        struct Plan {
            join_phase: usize,
            wire: u32,
            kill: Option<usize>,
        }
        let n_subs = rng.range(2, 5);
        let plan: Vec<Plan> = (0..n_subs)
            .map(|_| Plan {
                join_phase: rng.range(0, 2),
                wire: if rng.chance(0.5) { 3 } else { 2 },
                kill: if rng.chance(0.25) { Some(rng.range(20, 600)) } else { None },
            })
            .collect();
        // connect order: phase 0 joiners first (stable within a phase) —
        // this is also the accept order, i.e. the subscriber row order
        let mut join_order: Vec<usize> = (0..n_subs).collect();
        join_order.sort_by_key(|&i| plan[i].join_phase);

        let hub = LiveHub::new("bchost", 64, false);
        hub.ensure_channels(n_streams);
        let bc = Broadcaster::new(hub.clone(), 0x9E37, budget);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();

        let results: Vec<Option<(Vec<(u64, u32, u32)>, FanInStats)>> =
            std::thread::scope(|s| {
                let bc = &bc;
                let plan = &plan;
                {
                    let order = join_order.clone();
                    s.spawn(move || {
                        for &i in &order {
                            let (conn, _) = listener.accept().unwrap();
                            let conn =
                                KillAfter::new(conn, plan[i].kill.unwrap_or(usize::MAX));
                            let wire = plan[i].wire;
                            s.spawn(move || bc.serve_connection(conn, wire));
                        }
                    });
                }

                let mut clients: Vec<
                    Option<std::thread::ScopedJoinHandle<'_, Option<(Vec<(u64, u32, u32)>, FanInStats)>>>,
                > = (0..n_subs).map(|_| None).collect();
                let mut accepted = 0usize;
                for phase in 0..2 {
                    for &i in &join_order {
                        if plan[i].join_phase != phase {
                            continue;
                        }
                        let stream = TcpStream::connect(addr).unwrap();
                        accepted += 1;
                        wait_until("row registered", || bc.subscriber_stats().len() >= accepted);
                        clients[i] = Some(s.spawn(move || attach_client(stream)));
                    }
                    for (si, b) in batches.iter().enumerate() {
                        let (lo, hi) = if phase == 0 { (0, splits[si]) } else { (splits[si], b.len()) };
                        if lo < hi {
                            push_events(&hub, si, &b[lo..hi], lo);
                        }
                    }
                    bc.drain_to_ring();
                }
                hub.close_all();
                bc.pump();
                clients.into_iter().map(|h| h.unwrap().join().unwrap()).collect()
            });

        let rows = bc.subscriber_stats();
        assert_eq!(rows.len(), n_subs, "one row per accepted subscriber");
        assert_eq!(bc.stats().connections as usize, n_subs);
        for (k, &i) in join_order.iter().enumerate() {
            let row = &rows[k];
            assert_eq!(row.wire, plan[i].wire, "negotiation is per-connection: {row:?}");
            if let Some(err) = &row.error {
                // killed mid-stream (or mid-handshake): accounted as a
                // disconnect, no client-side guarantees — the OTHER
                // subscribers' checks below are the isolation property
                assert_eq!(row.disconnects, 1, "killed subscriber books one disconnect: {err}");
                continue;
            }
            assert_eq!(row.disconnects, 0, "{row:?}");
            assert_eq!(
                row.forwarded + row.lagged,
                total,
                "every event accounted exactly once (forwarded or gap): {row:?}"
            );
            if roomy {
                assert_eq!(row.lagged, 0, "nothing evicts under an entitled cursor: {row:?}");
            }
            let (merged, stats) = results[i]
                .as_ref()
                .unwrap_or_else(|| panic!("server completed but client {i} failed: {row:?}"));
            assert!(stats.per[0].error.is_none(), "{:?}", stats.per[0]);
            assert_eq!(
                stats.per[0].resume_gap, row.lagged,
                "both ends agree on the exact gap: {row:?}"
            );
            assert_eq!(merged.len() as u64, total - row.lagged, "{row:?}");
            if row.lagged == 0 {
                assert_eq!(merged, &expected, "a gapless subscriber merges the solo sequence");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Laggard demotion: a subscriber stalled past --max-lag is demoted to
// gap delivery — the ring moves on, the gap comes back as an exact
// ResumeGap, and the healthy subscriber never notices
// ---------------------------------------------------------------------------

/// Blocks the serve thread at its FIRST delivery-round write (the
/// handshake — everything before the first `flush` — passes through),
/// then releases it on `open()`. The write side is captured for frame-
/// level inspection; the read side serves exactly one scripted Resume.
struct GatedConn {
    input: Cursor<Vec<u8>>,
    out: Arc<Mutex<Vec<u8>>>,
    gate: Arc<Gate>,
    flushed_once: bool,
    passed: bool,
}

#[derive(Default)]
struct Gate {
    state: Mutex<GateState>,
    cv: Condvar,
}

#[derive(Default)]
struct GateState {
    blocked: bool,
    open: bool,
}

impl Gate {
    fn wait_blocked(&self) {
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut st = self.state.lock().unwrap();
        while !st.blocked {
            assert!(Instant::now() < deadline, "laggard never reached its gated write");
            let (g, _) = self.cv.wait_timeout(st, Duration::from_millis(20)).unwrap();
            st = g;
        }
    }

    fn open(&self) {
        self.state.lock().unwrap().open = true;
        self.cv.notify_all();
    }
}

impl Read for GatedConn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        self.input.read(buf)
    }
}

impl Write for GatedConn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.flushed_once && !self.passed {
            let mut st = self.gate.state.lock().unwrap();
            st.blocked = true;
            self.gate.cv.notify_all();
            while !st.open {
                st = self.gate.cv.wait(st).unwrap();
            }
            self.passed = true;
        }
        self.out.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        self.flushed_once = true;
        Ok(())
    }
}

#[test]
fn laggard_over_max_lag_is_demoted_to_an_exact_resume_gap() {
    const EPOCH: u64 = 0x1A66;
    let ev_len = event_len();
    let n_events = 10u64;
    let batch: Vec<(u64, u32, u32)> = (0..n_events).map(|i| (10 + i * 5, 0, 1)).collect();

    let hub = LiveHub::new("bchost", 64, false);
    hub.ensure_channels(1);
    push_events(&hub, 0, &batch[..3], 0);
    // ring holds exactly 3 event frames; one frame of lag is tolerated
    let bc = Broadcaster::new(hub.clone(), EPOCH, 3 * ev_len).with_max_lag(ev_len);
    bc.drain_to_ring();

    let mut resume = Vec::new();
    encode(&Frame::Resume { epoch: EPOCH, cursors: vec![] }, &mut resume);
    let out = Arc::new(Mutex::new(Vec::new()));
    let gate = Arc::new(Gate::default());
    let laggard = GatedConn {
        input: Cursor::new(resume),
        out: out.clone(),
        gate: gate.clone(),
        flushed_once: false,
        passed: false,
    };

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let healthy_merged = std::thread::scope(|s| {
        let bc = &bc;
        // healthy subscriber first (row 0, v3) — a real TCP client
        s.spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            bc.serve_connection(conn, 3)
        });
        let c0 = TcpStream::connect(addr).unwrap();
        let healthy = s.spawn(move || attach_client(c0));
        wait_until("healthy subscriber consumed phase 1", || {
            let rows = bc.subscriber_stats();
            !rows.is_empty() && rows[0].forwarded == 3
        });

        // laggard second (row 1, v2): handshakes, builds its first
        // round (cursor → 3), then stalls in the gated write
        let lag_serve = s.spawn(move || bc.serve_connection(laggard, 2));
        gate.wait_blocked();

        // push the remaining events one at a time, keeping the healthy
        // cursor current so only the laggard ever pins the ring: at
        // event 6 the laggard (4 frames behind > 1 allowed) is demoted,
        // and eviction proceeds past its cursor up to event 7
        for k in 3..n_events as usize {
            push_events(&hub, 0, &batch[k..k + 1], k);
            bc.drain_to_ring();
            wait_until("healthy subscriber caught up", || {
                bc.subscriber_stats()[0].forwarded == (k + 1) as u64
            });
        }
        hub.close_all();
        bc.pump();

        // release the laggard: it finishes the stalled round (events
        // 0–2), then gets ResumeGap{missed: 4} + events 7–9 + Eos
        gate.open();
        assert_eq!(lag_serve.join().unwrap(), ServeOutcome::Complete);
        healthy.join().unwrap()
    });

    let rows = bc.subscriber_stats();
    assert_eq!(rows.len(), 2);
    assert_eq!(
        (rows[1].forwarded, rows[1].lagged, rows[1].demoted),
        (6, 4, 1),
        "laggard: 3 pre-stall + 3 post-gap forwarded, 4 evicted as a gap, one demotion: {:?}",
        rows[1]
    );
    assert_eq!(rows[1].disconnects, 0, "demotion is not a disconnect: {:?}", rows[1]);
    assert!(rows[1].error.is_none());
    assert_eq!((rows[0].lagged, rows[0].demoted), (0, 0), "healthy row untouched: {:?}", rows[0]);
    assert_eq!(rows[0].forwarded, n_events);

    // the healthy subscriber's merge is the full, gapless sequence
    let (merged, stats) = healthy_merged.expect("healthy subscriber completed");
    let ts: Vec<u64> = merged.iter().map(|&(ts, _, _)| ts).collect();
    assert_eq!(ts, (0..n_events).map(|i| 10 + i * 5).collect::<Vec<_>>());
    assert_eq!(stats.per[0].resume_gap, 0);

    // frame-level: the laggard's wire carries exactly one ResumeGap of
    // 4, and exactly the six events its cursors say it was delivered
    let buf = out.lock().unwrap();
    let mut pos = 8; // preamble
    let mut gaps = Vec::new();
    let mut event_ts = Vec::new();
    let mut saw_eos = false;
    while pos < buf.len() {
        let (frame, used) = decode(&buf[pos..]).unwrap().expect("no torn frame in capture");
        pos += used;
        match frame {
            Frame::ResumeGap { stream, missed } => gaps.push((stream, missed)),
            Frame::Event { event, .. } => event_ts.push(event.ts),
            Frame::Eos { dropped, .. } => {
                saw_eos = true;
                assert_eq!(dropped, 0, "a demotion gap is the subscriber's, not the hub's");
            }
            _ => {}
        }
    }
    assert_eq!(gaps, vec![(0u32, 4u64)], "one exact gap frame for the evicted span");
    assert_eq!(event_ts, vec![10, 15, 20, 45, 50, 55], "events 0–2 then 7–9, nothing else");
    assert!(saw_eos, "the demoted subscriber still completes cleanly");
}

// ---------------------------------------------------------------------------
// Fault injection: killing one subscriber's connection mid-stream must
// not perturb the other subscribers' byte streams or ledgers
// ---------------------------------------------------------------------------

#[test]
fn killed_subscriber_does_not_perturb_the_others() {
    const EPOCH: u64 = 0x0517;
    let batches: Vec<Vec<(u64, u32, u32)>> = vec![
        (0..8).map(|i| (10 + i * 3, 0, 1)).collect(),
        (0..6).map(|i| (11 + i * 4, 0, 2)).collect(),
    ];
    let total: u64 = batches.iter().map(|b| b.len() as u64).sum();
    let expected = solo_expected("bchost", &batches);

    let hub = LiveHub::new("bchost", 64, false);
    hub.ensure_channels(batches.len());
    for (i, b) in batches.iter().enumerate() {
        push_events(&hub, i, b, 0);
    }
    let bc = Broadcaster::new(hub.clone(), EPOCH, 64 << 20);
    bc.drain_to_ring();

    // cut subscriber 1 one event past its handshake — mid-replay-round
    let kill_at = 8 + hello_wire_len("bchost", 2, EPOCH) + event_len() + 4;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let wires = [3u32, 2, 3];

    let results: Vec<Option<(Vec<(u64, u32, u32)>, FanInStats)>> = std::thread::scope(|s| {
        let bc = &bc;
        s.spawn(move || {
            for (i, wire) in wires.into_iter().enumerate() {
                let (conn, _) = listener.accept().unwrap();
                let budget = if i == 1 { kill_at } else { usize::MAX };
                let conn = KillAfter::new(conn, budget);
                s.spawn(move || bc.serve_connection(conn, wire));
            }
        });
        let mut handles = Vec::new();
        for i in 0..3 {
            let stream = TcpStream::connect(addr).unwrap();
            wait_until("row registered", || bc.subscriber_stats().len() > i);
            handles.push(s.spawn(move || attach_client(stream)));
        }
        hub.close_all();
        bc.pump();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let rows = bc.subscriber_stats();
    assert_eq!(rows.len(), 3);
    assert_eq!(rows[1].disconnects, 1, "{:?}", rows[1]);
    assert!(rows[1].error.is_some(), "{:?}", rows[1]);
    match &results[1] {
        None => {} // died during handshake bookkeeping — fine
        Some((merged, stats)) => {
            assert!(stats.per[0].error.is_some(), "the cut is visible client-side");
            assert!((merged.len() as u64) < total, "the killed subscriber got a partial view");
        }
    }

    for i in [0usize, 2] {
        let row = &rows[i];
        assert_eq!(row.forwarded, total, "survivor delivered everything: {row:?}");
        assert_eq!((row.lagged, row.demoted, row.disconnects), (0, 0, 0), "{row:?}");
        assert!(row.error.is_none(), "{row:?}");
        let (merged, stats) = results[i]
            .as_ref()
            .unwrap_or_else(|| panic!("survivor {i} failed: {row:?}"));
        assert_eq!(merged, &expected, "survivor {i} merges the untouched solo sequence");
        assert_eq!(stats.per[0].resume_gap, 0);
        assert!(stats.per[0].error.is_none());
        assert_eq!(stats.per[0].wire_version, wires[i]);
    }
}
