//! Self-telemetry integration tests.
//!
//! The acceptance bar: the scrape endpoint and the end-of-run reports
//! are views over the SAME registry, so the numbers an operator watches
//! mid-run and the numbers the summary prints can never disagree — a
//! fan-in run is scraped over real HTTP and every sample is asserted
//! equal to `LiveStats` / `FanInStats` / `OriginStats`. A deterministic
//! local run pins the exact nonzero counter set (golden), concurrent
//! feeders pin scrape integrity under load, and the `iprof health
//! --strict` gate is driven through the real binary for its exit codes.

use std::io::Cursor;
use std::sync::Arc;
use std::thread;
use thapi::analysis::{AnalysisSink, EventMsg, TallySink};
use thapi::live::{run_live_pipeline, LiveHub, LiveSource};
use thapi::remote::{publish_with, FanIn, PublishStats};
use thapi::telemetry::{
    origin_series_label, parse_exposition, scrape, HealthSummary, Registry, Sample,
    TelemetryOptions, TelemetryServer,
};

/// Decode a registry-class message through `hub` (same idiom as the
/// fan-in tests: the class id must resolve on the attach side).
fn reg_msg(hub: &LiveHub, name: &str, ts: u64, rank: u32, tid: u32) -> EventMsg {
    let class = thapi::model::class_by_name(name).unwrap();
    hub.decode(rank, tid, class.id, ts, &0u64.to_le_bytes()).unwrap()
}

/// Sum of every sample of an unlabeled metric (0.0 if absent).
fn val(samples: &[Sample], name: &str) -> f64 {
    samples.iter().filter(|s| s.name == name).map(|s| s.value).sum()
}

/// The one sample of `name` whose label matches, or 0.0.
fn lval(samples: &[Sample], name: &str, key: &str, label: &str) -> f64 {
    samples
        .iter()
        .find(|s| s.name == name && s.label(key) == Some(label))
        .map(|s| s.value)
        .unwrap_or(0.0)
}

/// Publish a small deterministic 2-stream feed into an in-memory v3
/// wire; returns the wire and the publisher's own accounting.
fn build_wire(hostname: &str) -> (Vec<u8>, PublishStats, Arc<LiveHub>) {
    let hub = LiveHub::new(hostname, 64, false);
    hub.ensure_channels(2);
    hub.push_batch(
        0,
        vec![
            reg_msg(&hub, "lttng_ust_ze:zeInit_entry", 10, 0, 1),
            reg_msg(&hub, "lttng_ust_ze:zeInit_exit", 20, 0, 1),
            reg_msg(&hub, "lttng_ust_ze:zeInit_entry", 40, 0, 1),
            reg_msg(&hub, "lttng_ust_ze:zeInit_exit", 70, 0, 1),
        ],
    );
    hub.push_batch(
        1,
        vec![
            reg_msg(&hub, "lttng_ust_ze:zeInit_entry", 15, 0, 2),
            reg_msg(&hub, "lttng_ust_ze:zeInit_exit", 35, 0, 2),
        ],
    );
    hub.close_all();
    let mut buf = Vec::new();
    let stats = publish_with(&hub, &mut buf, thapi::remote::VERSION).unwrap();
    (buf, stats, hub)
}

// ---------------------------------------------------------------------------
// Golden: a deterministic local run produces exactly the expected
// counter set — nothing more, nothing less
// ---------------------------------------------------------------------------

#[test]
fn deterministic_run_yields_exact_golden_counters() {
    let hub = LiveHub::new("gold", 64, false);
    hub.ensure_channels(2);
    hub.push_batch(
        0,
        vec![
            reg_msg(&hub, "lttng_ust_ze:zeInit_entry", 10, 0, 1),
            reg_msg(&hub, "lttng_ust_ze:zeInit_exit", 20, 0, 1),
        ],
    );
    hub.push_batch(
        1,
        vec![
            reg_msg(&hub, "lttng_ust_ze:zeInit_entry", 12, 0, 2),
            reg_msg(&hub, "lttng_ust_ze:zeInit_exit", 30, 0, 2),
        ],
    );
    hub.close_all();
    let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let pipe = run_live_pipeline(LiveSource::new(hub.clone()), &mut sinks, None, |_| {});
    assert_eq!(pipe.latency.merged, 4);

    let reg = hub.telemetry();
    assert_eq!(reg.live_events_received.get(), 4);
    assert_eq!(reg.live_events_dropped.get(), 0);
    assert_eq!(reg.live_beacons.get(), 0);
    assert_eq!(reg.live_queue_depth.get(), 0, "drained run must settle at zero depth");
    assert_eq!(reg.live_channels.get(), 2);
    assert_eq!(reg.merge_events.get(), 4);
    assert_eq!(reg.sink_refresh.get(), 0, "no --live-refresh, no sweeps");
    assert_eq!(reg.publish_events.get(), 0, "no publisher in a local run");

    // the exposition's nonzero samples are EXACTLY the expected set
    // (time-derived meters excluded: residence latency and gate waits
    // depend on scheduling, not on the event feed)
    let text = reg.render_prometheus();
    let samples = parse_exposition(&text).expect("own exposition must parse");
    let nondeterministic =
        ["thapi_merge_latency_seconds_total", "thapi_merge_gate_waits_total"];
    let mut nonzero: Vec<(String, f64)> = samples
        .iter()
        .filter(|s| s.value != 0.0 && !nondeterministic.contains(&s.name.as_str()))
        .map(|s| {
            let labels: Vec<String> =
                s.labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
            (format!("{}{{{}}}", s.name, labels.join(",")), s.value)
        })
        .collect();
    nonzero.sort();
    assert_eq!(
        nonzero,
        vec![
            ("thapi_live_channels{}".to_string(), 2.0),
            ("thapi_live_events_received_total{}".to_string(), 4.0),
            ("thapi_merge_events_total{}".to_string(), 4.0),
            ("thapi_shard_feed_total{shard=0}".to_string(), 4.0),
            ("thapi_shard_merged_total{shard=0}".to_string(), 4.0),
        ],
        "golden counter set drifted:\n{text}"
    );

    // the zero-valued per-stream series are still registered (catalog
    // stability: a scraper sees every stream from the first scrape on)
    for stream in ["0", "1"] {
        assert_eq!(lval(&samples, "thapi_channel_dropped_total", "stream", stream), 0.0);
        assert_eq!(lval(&samples, "thapi_channel_queue_depth", "stream", stream), 0.0);
        assert!(samples
            .iter()
            .any(|s| s.name == "thapi_channel_dropped_total"
                && s.label("stream") == Some(stream)));
    }
}

// ---------------------------------------------------------------------------
// Serve side: the publisher hub's registry mirrors PublishStats exactly
// ---------------------------------------------------------------------------

#[test]
fn publisher_registry_mirrors_publish_stats_exactly() {
    let (_wire, stats, hub) = build_wire("pubnode");
    let reg = hub.telemetry();
    assert_eq!(stats.events, 6);
    assert_eq!(stats.connections, 1);
    assert_eq!(reg.publish_events.get(), stats.events);
    assert_eq!(reg.publish_frames.get(), stats.frames);
    assert_eq!(reg.publish_bytes.get(), stats.bytes);
    assert_eq!(reg.publish_batches.get(), stats.batches);
    assert_eq!(reg.publish_dict_defs.get(), stats.dict_defs);
    assert_eq!(reg.publish_dict_refs.get(), stats.dict_refs);
    assert_eq!(reg.publish_connections.get(), stats.connections);
    assert_eq!(reg.publish_replayed.get(), stats.replayed);
    assert_eq!(reg.publish_gap_events.get(), stats.gaps);
    assert!(stats.batches > 0, "v3 wire must batch");
    assert!(reg.publish_rounds.get() > 0);
}

// ---------------------------------------------------------------------------
// Acceptance: a fan-in run scraped over real HTTP reports numbers equal
// to the end-of-run LiveStats / FanInStats / OriginStats — same registry
// ---------------------------------------------------------------------------

#[test]
fn fanin_endpoint_scrape_equals_end_of_run_report() {
    let (wire, _pub_stats, _pub_hub) = build_wire("pubnode");

    let fan = FanIn::open(vec![Cursor::new(wire)], 64).unwrap();
    let hub = fan.hub().clone();
    let server = TelemetryServer::bind("127.0.0.1:0", hub.telemetry().clone()).unwrap();
    let addr = server.local_addr().to_string();

    let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let pipe = run_live_pipeline(fan.source(), &mut sinks, None, |_| {});
    let local = hub.stats();
    let origins = hub.origin_stats();
    let stats = fan.finish().unwrap();

    let text = scrape(&addr).unwrap();
    server.shutdown();
    let samples = parse_exposition(&text).expect("endpoint exposition must parse");

    // pipeline-level equality
    assert_eq!(local.received, 6);
    assert_eq!(val(&samples, "thapi_live_events_received_total"), local.received as f64);
    assert_eq!(val(&samples, "thapi_live_events_dropped_total"), local.dropped as f64);
    assert_eq!(val(&samples, "thapi_merge_events_total"), pipe.latency.merged as f64);
    assert_eq!(val(&samples, "thapi_live_queue_depth"), 0.0);

    // per-origin equality: every scrape sample equals the reader's own
    // end-of-run accounting, series keyed by the shared "<idx>:<host>"
    let per = &stats.per[0];
    let origin = origin_series_label(0, "pubnode");
    let ol = |name: &str| lval(&samples, name, "origin", &origin);
    assert_eq!(ol("thapi_origin_events_total"), per.events as f64);
    assert_eq!(ol("thapi_origin_frames_total"), per.frames as f64);
    assert_eq!(ol("thapi_origin_batches_total"), per.batches as f64);
    assert_eq!(ol("thapi_origin_wire_version"), per.wire_version as f64);
    assert_eq!(ol("thapi_origin_reconnects_total"), 0.0);
    assert_eq!(ol("thapi_origin_resume_gap_events_total"), origins[0].resume_gaps as f64);
    assert_eq!(ol("thapi_origin_remote_dropped_total"), origins[0].remote_dropped as f64);
    assert_eq!(per.events, 6);
    assert_eq!(per.wire_version, thapi::remote::VERSION);

    // lossless feed: the health view over the same scrape agrees
    assert_eq!(origins[0].known_dropped(), 0);
    let health = HealthSummary::from_samples(&samples);
    assert_eq!(health.known_loss(), 0);
    assert_eq!(health.received, local.received);
}

// ---------------------------------------------------------------------------
// Concurrency smoke: scrapes taken while feeders hammer the registry
// always parse, and the settled totals are exact
// ---------------------------------------------------------------------------

#[test]
fn scrapes_parse_while_concurrent_feeders_run() {
    const K: usize = 4;
    const N: usize = 400;
    let hub = LiveHub::new("smoke", 1 << 12, false);
    hub.ensure_channels(K);
    let origin_a = hub.register_origin("nodeA");
    let origin_b = hub.register_origin("nodeB");
    let server = TelemetryServer::bind("127.0.0.1:0", hub.telemetry().clone()).unwrap();
    let addr = server.local_addr().to_string();

    thread::scope(|s| {
        for t in 0..K {
            let hub = &hub;
            s.spawn(move || {
                for i in 0..N {
                    hub.push_batch(
                        t,
                        vec![reg_msg(hub, "lttng_ust_ze:zeInit_entry", (i + 1) as u64, 0, t as u32)],
                    );
                }
            });
        }
        // two ledger writers racing the feeders: cumulative wire drops
        // on one origin, resume gaps on the other
        let hub2 = &hub;
        s.spawn(move || {
            for c in 1..=N as u64 {
                hub2.record_origin_drops(origin_a, 0, c);
            }
        });
        s.spawn(move || {
            for _ in 0..N {
                hub2.record_origin_gap(origin_b, 0, 1);
            }
        });
        // scrape the endpoint the whole time: every response must be
        // well-formed exposition, and monotone totals can lag but never
        // overshoot what the feeders will have written
        for _ in 0..25 {
            let text = scrape(&addr).unwrap();
            let samples = parse_exposition(&text).expect("mid-run scrape must parse");
            assert!(val(&samples, "thapi_live_events_received_total") <= (K * N) as f64);
            assert!(
                lval(&samples, "thapi_origin_remote_dropped_total", "origin",
                    &origin_series_label(origin_a, "nodeA")) <= N as f64
            );
        }
    });

    let text = scrape(&addr).unwrap();
    server.shutdown();
    let samples = parse_exposition(&text).unwrap();
    assert_eq!(val(&samples, "thapi_live_events_received_total"), (K * N) as f64);
    assert_eq!(val(&samples, "thapi_live_events_dropped_total"), 0.0);
    assert_eq!(val(&samples, "thapi_live_queue_depth"), (K * N) as f64, "nothing merged yet");
    for t in 0..K {
        assert_eq!(
            lval(&samples, "thapi_channel_queue_depth", "stream", &t.to_string()),
            N as f64
        );
    }
    assert_eq!(
        lval(&samples, "thapi_origin_remote_dropped_total", "origin",
            &origin_series_label(origin_a, "nodeA")),
        N as f64
    );
    assert_eq!(
        lval(&samples, "thapi_origin_resume_gap_events_total", "origin",
            &origin_series_label(origin_b, "nodeB")),
        N as f64
    );
    // the hub's book-of-record agrees with the scrape
    let origins = hub.origin_stats();
    assert_eq!(origins[origin_a].remote_dropped, N as u64);
    assert_eq!(origins[origin_b].resume_gaps, N as u64);
}

// ---------------------------------------------------------------------------
// Broadcast serve: the per-subscriber metric family is a live view over
// the same rows ServeReport.subscribers carries — a mid-run scrape
// equals the Broadcaster's accounting at that instant, and the final
// scrape equals the final rows
// ---------------------------------------------------------------------------

/// In-memory subscriber for a broadcast session: the read side scripts
/// the handshake, the write side swallows the publisher's bytes.
struct ScriptedSub {
    input: Cursor<Vec<u8>>,
}

impl ScriptedSub {
    /// Answers the resumable Hello with a fresh Resume — a conforming
    /// subscriber that consumes the whole session.
    fn resuming(epoch: u64) -> ScriptedSub {
        let mut resume = Vec::new();
        thapi::remote::encode(
            &thapi::remote::Frame::Resume { epoch, cursors: vec![] },
            &mut resume,
        );
        ScriptedSub { input: Cursor::new(resume) }
    }

    /// Hangs up instead of completing the handshake — a disconnect.
    fn mute() -> ScriptedSub {
        ScriptedSub { input: Cursor::new(Vec::new()) }
    }
}

impl std::io::Read for ScriptedSub {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        std::io::Read::read(&mut self.input, buf)
    }
}

impl std::io::Write for ScriptedSub {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[test]
fn broadcast_subscriber_family_scrape_equals_serve_rows() {
    use thapi::remote::{encode, Broadcaster, Frame, ServeOutcome, WireEvent};
    use thapi::tracer::encoder::FieldValue;
    const EPOCH: u64 = 0x5CB5;
    const N: u64 = 12;

    // one encoded event frame, to size the ring in whole events
    let event_len = {
        let mut buf = Vec::new();
        encode(
            &Frame::Event {
                stream: 0,
                event: WireEvent {
                    ts: 10,
                    rank: 0,
                    tid: 1,
                    class_id: thapi::model::class_by_name("lttng_ust_ze:zeInit_entry")
                        .unwrap()
                        .id,
                    fields: vec![FieldValue::U64(0)],
                },
            },
            &mut buf,
        );
        buf.len()
    };

    // one stream, 12 events, a ring that keeps only 3 event frames:
    // everything older is evicted BEFORE any subscriber attaches, so
    // every subscriber resumes into the same exact, nonzero gap
    let hub = LiveHub::new("bcast", 64, false);
    hub.ensure_channels(1);
    let msgs: Vec<EventMsg> = (0..N)
        .map(|i| {
            let name = if i % 2 == 0 {
                "lttng_ust_ze:zeInit_entry"
            } else {
                "lttng_ust_ze:zeInit_exit"
            };
            reg_msg(&hub, name, 10 + i * 5, 0, 1)
        })
        .collect();
    hub.push_batch(0, msgs);
    hub.close_all();
    let bc = Broadcaster::new(hub.clone(), EPOCH, 3 * event_len);
    bc.pump();

    let server = TelemetryServer::bind("127.0.0.1:0", hub.telemetry().clone()).unwrap();
    let addr = server.local_addr().to_string();
    let sval = |samples: &[Sample], name: &str, id: &str| lval(samples, name, "subscriber", id);

    // subscriber 0 (v3) completes; the MID-RUN scrape — subscriber 1
    // not yet attached — must equal the rows at this instant
    assert_eq!(bc.serve_connection(ScriptedSub::resuming(EPOCH), 3), ServeOutcome::Complete);
    let rows = bc.subscriber_stats();
    assert_eq!(rows.len(), 1);
    assert!(rows[0].lagged > 0, "the tight ring must have evicted: {:?}", rows[0]);
    assert_eq!(rows[0].forwarded + rows[0].lagged, N, "{:?}", rows[0]);
    let samples = parse_exposition(&scrape(&addr).unwrap()).unwrap();
    assert_eq!(
        sval(&samples, "thapi_subscriber_forwarded_events_total", "0"),
        rows[0].forwarded as f64
    );
    assert_eq!(
        sval(&samples, "thapi_subscriber_lagged_events_total", "0"),
        rows[0].lagged as f64
    );
    assert_eq!(sval(&samples, "thapi_subscriber_demotions_total", "0"), 0.0);
    assert_eq!(sval(&samples, "thapi_subscriber_disconnects_total", "0"), 0.0);
    assert!(
        !samples.iter().any(|s| s.label("subscriber") == Some("1")),
        "no series for a subscriber that has not attached"
    );

    // subscriber 1 (v2) completes with the same gap; subscriber 2
    // hangs up mid-handshake — a disconnect row, not an event row
    assert_eq!(bc.serve_connection(ScriptedSub::resuming(EPOCH), 2), ServeOutcome::Complete);
    assert!(matches!(bc.serve_connection(ScriptedSub::mute(), 3), ServeOutcome::Lost(_)));

    // final scrape == the final rows (the exact Vec ServeReport carries)
    let rows = bc.subscriber_stats();
    assert_eq!(rows.len(), 3);
    assert_eq!(rows[1].lagged, rows[0].lagged, "same ring, same gap");
    assert_eq!(rows[2].disconnects, 1, "{:?}", rows[2]);
    let samples = parse_exposition(&scrape(&addr).unwrap()).unwrap();
    server.shutdown();
    for row in &rows {
        let id = row.id.to_string();
        let check = |name: &str, v: u64| {
            assert_eq!(sval(&samples, name, &id), v as f64, "subscriber {id}: {row:?}");
        };
        check("thapi_subscriber_forwarded_events_total", row.forwarded);
        check("thapi_subscriber_lagged_events_total", row.lagged);
        check("thapi_subscriber_demotions_total", row.demoted);
        check("thapi_subscriber_disconnects_total", row.disconnects);
    }

    // the health view groups the same rows — and a subscriber's lag is
    // NOT pipeline loss (it resurfaces as resume gaps on that
    // subscriber's own attach side, where --live-strict already gates)
    let health = HealthSummary::from_samples(&samples);
    assert_eq!(health.subscribers.len(), 3);
    assert_eq!(health.subscribers[0].forwarded, rows[0].forwarded);
    assert_eq!(health.subscribers[0].lagged, rows[0].lagged);
    assert_eq!(health.subscribers[2].disconnects, 1);
    assert_eq!(health.known_loss(), 0, "subscriber lag is not hub-side loss");
}

// ---------------------------------------------------------------------------
// `iprof health --strict`: exit codes through the real binary
// ---------------------------------------------------------------------------

#[test]
fn health_strict_gate_exit_codes() {
    let reg = Registry::new();
    reg.live_events_received.add(10);
    reg.merge_events.add(7);
    reg.live_events_dropped.add(3);
    reg.origin_resume_gaps.with_label(&origin_series_label(0, "n1")).add(2);
    let server = TelemetryServer::bind("127.0.0.1:0", reg.clone()).unwrap();
    let addr = server.local_addr().to_string();
    let bin = env!("CARGO_BIN_EXE_iprof");
    let health = |extra: &[&str]| {
        let mut cmd = std::process::Command::new(bin);
        cmd.arg("health").arg(&addr).args(extra);
        cmd.output().unwrap()
    };

    // non-strict: always exit 0, print the summary
    let out = health(&[]);
    assert!(out.status.success(), "non-strict must succeed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("known loss: 5 event(s)"), "summary must total the loss: {stdout}");

    // strict with the default zero threshold: lossy feed gates
    let out = health(&["--strict"]);
    assert!(!out.status.success(), "known loss 5 must fail --strict");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("known loss"), "gate must say why: {stderr}");

    // a threshold at the actual loss passes
    let out = health(&["--strict", "--max-drops", "5"]);
    assert!(out.status.success(), "loss == threshold must pass: {out:?}");
    let out = health(&["--strict", "--max-drops", "4"]);
    assert!(!out.status.success(), "loss > threshold must fail");
    server.shutdown();

    // a clean registry passes strict outright
    let clean = Registry::new();
    clean.live_events_received.add(4);
    let server = TelemetryServer::bind("127.0.0.1:0", clean).unwrap();
    let addr = server.local_addr().to_string();
    let out = std::process::Command::new(bin)
        .args(["health", &addr, "--strict"])
        .output()
        .unwrap();
    assert!(out.status.success(), "lossless feed must pass --strict: {out:?}");
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Coordinator wiring: run_fanin's --telemetry-json final snapshot holds
// the settled report numbers
// ---------------------------------------------------------------------------

#[test]
fn run_fanin_final_json_snapshot_matches_report() {
    let (wire, _stats, _hub) = build_wire("pubnode");
    let dir = std::env::temp_dir().join(format!("thapi-tele-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("final.json");
    let opts = TelemetryOptions { json_path: Some(path.clone()), ..Default::default() };

    let sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let report = thapi::coordinator::run_fanin(
        vec![Cursor::new(wire)],
        64,
        sinks,
        None,
        |_| {},
        &opts,
    )
    .unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    assert!(text.contains("\"bench\": \"telemetry\""));
    // the final snapshot is written after the pipeline joins, so it
    // carries the same settled numbers the report prints (BenchJson
    // rows are "name" then "value" on the following line)
    let expect = |name: &str, v: f64| {
        let lines: Vec<&str> = text.lines().collect();
        let i = lines
            .iter()
            .position(|l| l.contains(&format!("\"name\": \"{name}\"")))
            .unwrap_or_else(|| panic!("{name} missing from snapshot:\n{text}"));
        assert!(
            lines[i + 1].contains(&format!("\"value\": {v:.3}")),
            "{name} must equal the report's {v}: {}",
            lines[i + 1]
        );
    };
    expect("thapi_live_events_received_total", report.local.received as f64);
    expect("thapi_merge_events_total", report.latency.merged as f64);
    expect("thapi_live_events_dropped_total", report.local.dropped as f64);
    assert_eq!(report.local.received, 6);
    assert_eq!(report.known_dropped(), 0);
}
