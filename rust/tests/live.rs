//! Live-analysis subsystem tests: ordering equivalence, backpressure,
//! beacons, and whole-stack `run_live` golden comparisons.
//!
//! The acceptance bar: the on-line path (`consumer thread → bounded
//! channels + beacons → LiveSource merge → sinks`) must produce output
//! **byte-identical** to the post-mortem path (`collect → parse_trace →
//! MessageSource → sinks`) over the same events, while never blocking
//! the producing side.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};
use thapi::analysis::{
    self, AnalysisSink, EventMsg, MessageSource, ParsedTrace, TallySink, TimelineSink,
};
use thapi::apps::{hecbench, spechpc};
use thapi::coordinator::{run_live, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::live::{replay_trace, LiveConfig, LiveHub, LiveSource};
use thapi::tracer::btf::{DecodedClass, Metadata};
use thapi::util::{prop, Rng};

/// Global-session tests cannot overlap.
static LOCK: Mutex<()> = Mutex::new(());
fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn app(name: &str) -> std::sync::Arc<dyn thapi::apps::Workload> {
    hecbench::suite()
        .into_iter()
        .chain(spechpc::suite())
        .find(|a| a.name() == name)
        .unwrap_or_else(|| panic!("app {name}"))
}

// ---------------------------------------------------------------------------
// Property: live merge == post-mortem merge on randomized traces
// ---------------------------------------------------------------------------

/// Synthetic multi-stream trace with deliberate in-stream and
/// cross-stream timestamp ties; stream index encoded in `rank`, in-stream
/// position in `tid`, so the full merge order is observable.
fn synthetic_parsed(rng: &mut Rng) -> ParsedTrace {
    let class = Arc::new(DecodedClass {
        id: 0,
        name: "lttng_ust_ze:zeInit_entry".to_string(),
        api: "ZE".to_string(),
        flags: "h".to_string(),
        fields: vec![],
    });
    let hostname: Arc<str> = Arc::from("livenode");
    let n_streams = rng.range(1, 7);
    let mut streams = Vec::with_capacity(n_streams + 1);
    for si in 0..n_streams {
        let mut ts = rng.below(4);
        let n = rng.range(0, 50);
        let mut events = Vec::with_capacity(n);
        for i in 0..n {
            ts += rng.below(3); // zero increments force equal timestamps
            events.push(EventMsg {
                ts,
                rank: si as u32,
                tid: i as u32,
                hostname: hostname.clone(),
                class: class.clone(),
                fields: vec![],
            });
        }
        streams.push(events);
    }
    // one permanently quiet stream: it will only ever publish beacons —
    // the merge must advance past it without a single event
    streams.push(Vec::new());
    ParsedTrace { metadata: Metadata::default(), streams }
}

/// Feed a synthetic parsed trace's streams through a hub the way the
/// consumer would: per-stream chunks through the lossless blocking path,
/// each followed by a beacon at the next pending event's timestamp (a
/// valid watermark: per stream, future events start exactly there).
/// Quiet/exhausted streams beacon far ahead, then everything closes.
///
/// One feeder thread per stream, deliberately: a blocked feeder only
/// ever waits on the merge draining its own full queue, and the merge is
/// only vetoed by *empty* channels, so no wait cycle can form (a single
/// round-robin feeder could deadlock: blocked on a full stream A while
/// the merge waits for stream B's next equal-timestamp event).
fn feed_synthetic(hub: &LiveHub, streams: &[Vec<EventMsg>], seed: u64) {
    hub.ensure_channels(streams.len());
    let mut max_ts = 0u64;
    for s in streams {
        if let Some(last) = s.last() {
            max_ts = max_ts.max(last.ts);
        }
    }
    std::thread::scope(|scope| {
        for (i, s) in streams.iter().enumerate() {
            let mut rng = Rng::new(seed.wrapping_add(i as u64));
            scope.spawn(move || {
                let mut off = 0usize;
                while off < s.len() {
                    let end = (off + rng.range(1, 6)).min(s.len());
                    hub.feed_blocking(i, s[off..end].to_vec());
                    off = end;
                    if let Some(next) = s.get(off) {
                        // future events on this stream start exactly here
                        hub.beacon(i, next.ts);
                    }
                }
                // exhausted (or born quiet): beacon past everything, as a
                // wall-clock consumer beacon would, then close
                hub.beacon(i, max_ts + 1);
                hub.close(i);
            });
        }
    });
    hub.close_all();
}

/// LiveSource output is element-for-element identical to the post-mortem
/// MessageSource on randomized multi-stream traces — including equal
/// timestamps (tie-break by stream, then in-stream order) and a quiet
/// stream that only beacons.
#[test]
fn prop_live_source_is_byte_identical_to_postmortem_merge() {
    prop::check(40, 0x11fe, |rng| {
        let parsed = synthetic_parsed(rng);
        let expected: Vec<(u64, u32, u32)> =
            MessageSource::new(&parsed).map(|m| (m.ts, m.rank, m.tid)).collect();

        let hub = LiveHub::new("livenode", 8, false);
        let source = LiveSource::new(hub.clone());
        let seed = rng.next_u64();
        let got = std::thread::scope(|s| {
            let hub = &hub;
            let streams = &parsed.streams;
            let feeder = s.spawn(move || feed_synthetic(hub, streams, seed));
            let got: Vec<(u64, u32, u32)> = source.map(|m| (m.ts, m.rank, m.tid)).collect();
            feeder.join().unwrap();
            got
        });
        assert_eq!(got, expected, "live merge must equal the post-mortem merge exactly");
    });
}

// ---------------------------------------------------------------------------
// Backpressure: tiny channels drop-and-count, never block
// ---------------------------------------------------------------------------

#[test]
fn tiny_channels_drop_and_count_without_blocking_the_producer() {
    let class = Arc::new(DecodedClass {
        id: 0,
        name: "lttng_ust_ze:zeInit_entry".to_string(),
        api: "ZE".to_string(),
        flags: "h".to_string(),
        fields: vec![],
    });
    let hub = LiveHub::new("droptest", 2, false);
    hub.ensure_channels(1);
    let n = 10_000u64;
    let t0 = Instant::now();
    // Nothing consumes: a blocking channel would deadlock right here.
    for i in 0..n {
        hub.push_batch(
            0,
            vec![EventMsg {
                ts: i,
                rank: 0,
                tid: i as u32,
                hostname: Arc::from("droptest"),
                class: class.clone(),
                fields: vec![],
            }],
        );
    }
    let push_time = t0.elapsed();
    assert!(
        push_time < Duration::from_secs(10),
        "try-push must never block (took {push_time:?})"
    );
    let stats = hub.stats();
    assert_eq!(stats.received + stats.dropped, n, "every event accounted for");
    assert_eq!(stats.received, 2, "only `depth` events fit");
    assert!(stats.dropped > 0);
    // the survivors still merge, in order
    hub.close_all();
    let survivors: Vec<u64> = LiveSource::new(hub).map(|m| m.ts).collect();
    assert_eq!(survivors, vec![0, 1]);
}

// ---------------------------------------------------------------------------
// Whole stack: run_live vs post-mortem on the identical run
// ---------------------------------------------------------------------------

/// `iprof --live -a tally,timeline` byte-identity: run ONE workload with
/// retain on, drive tally+timeline on-line, then re-analyze the retained
/// (identical) trace post-mortem and compare both reports byte-for-byte.
#[test]
fn run_live_tally_and_timeline_are_byte_identical_to_postmortem() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = Node::new(NodeConfig::test_small());
    let live_cfg = LiveConfig { channel_depth: 1 << 16, retain: true, refresh: None };
    let sinks: Vec<Box<dyn AnalysisSink + Send>> =
        vec![Box::new(TallySink::new()), Box::new(TimelineSink::new())];
    let r = run_live(
        &node,
        app("lrn-hip").as_ref(),
        &IprofConfig::default(),
        &live_cfg,
        sinks,
        |_| {},
    );
    assert_eq!(r.live.dropped, 0, "deep channels must not drop");
    assert_eq!(r.stats.dropped, 0, "rings must not drop at this scale");
    assert_eq!(r.live.received, r.stats.written, "every written event reached the merge");
    assert_eq!(r.latency.merged, r.stats.written, "every event was analyzed");

    let parsed = analysis::parse_trace(r.trace.as_ref().unwrap()).unwrap();
    let mut pm: Vec<Box<dyn AnalysisSink>> =
        vec![Box::new(TallySink::new()), Box::new(TimelineSink::new())];
    let pm_reports = analysis::run_pipeline(&parsed, &mut pm);
    assert_eq!(
        r.reports[0].payload(),
        pm_reports[0].payload(),
        "live tally must be byte-identical"
    );
    assert_eq!(
        r.reports[1].payload(),
        pm_reports[1].payload(),
        "live timeline must be byte-identical"
    );
}

/// Live analysis observes events while the application is still running:
/// a long-lived quiet thread (one early event, then silence) must not
/// stall the merge, thanks to consumer beacons.
#[test]
fn live_merge_advances_past_a_quiet_thread_mid_run() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = Node::new(NodeConfig::test_small());
    let live_cfg = LiveConfig { channel_depth: 1 << 14, retain: false, refresh: None };

    struct QuietThenBusy;
    impl thapi::apps::Workload for QuietThenBusy {
        fn name(&self) -> &str {
            "quiet-then-busy"
        }
        fn backend(&self) -> &'static str {
            "ZE"
        }
        fn run(&self, _node: &std::sync::Arc<Node>) {
            let entry = thapi::model::class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
            let exit = thapi::model::class_by_name("lttng_ust_ze:zeInit_exit").unwrap();
            let (tx, rx) = std::sync::mpsc::channel::<()>();
            // quiet thread: one span, then alive-but-silent until released
            let quiet = std::thread::spawn(move || {
                thapi::tracer::emit(entry, |e| {
                    e.u64(0);
                });
                thapi::tracer::emit(exit, |e| {
                    e.u64(0);
                });
                let _ = rx.recv();
            });
            // busy thread: keeps emitting while the quiet thread idles —
            // these events can only be merged if beacons advance the
            // quiet stream's watermark
            for _ in 0..2000 {
                thapi::tracer::emit(entry, |e| {
                    e.u64(0);
                });
                thapi::tracer::emit(exit, |e| {
                    e.u64(0);
                });
            }
            std::thread::sleep(Duration::from_millis(30));
            let _ = tx.send(());
            quiet.join().unwrap();
        }
    }

    let sinks: Vec<Box<dyn AnalysisSink + Send>> = vec![Box::new(TallySink::new())];
    let r = run_live(&node, &QuietThenBusy, &IprofConfig::default(), &live_cfg, sinks, |_| {});
    assert_eq!(r.live.dropped, 0);
    assert_eq!(r.latency.merged, r.stats.written);
    assert!(r.live.beacons > 0, "the quiet thread forces beacon-driven progress");
    // the 30ms idle window proves events merged before teardown: if the
    // merge had waited for close_all, every message would be >= 30ms stale
    assert!(
        r.latency.mean() < Duration::from_millis(30),
        "mean latency {:?} suggests the merge only ran at teardown",
        r.latency.mean()
    );
    let text = r.reports[0].payload().unwrap();
    assert!(text.contains("zeInit"));
}

// ---------------------------------------------------------------------------
// Replay: recorded trace through the live machinery == post-mortem
// ---------------------------------------------------------------------------

#[test]
fn replayed_trace_reports_match_postmortem_even_with_tiny_channels() {
    let _g = lock();
    std::env::set_var("THAPI_APP_SCALE", "0.1");
    let node = Node::new(NodeConfig::test_small());
    let r = thapi::coordinator::run(&node, app("saxpy-ze").as_ref(), &IprofConfig::default());
    let trace = r.trace.as_ref().unwrap();

    // post-mortem reference
    let parsed = analysis::parse_trace(trace).unwrap();
    let mut pm: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let pm_reports = analysis::run_pipeline(&parsed, &mut pm);

    // live replay through depth-8 channels: lossless blocking feed
    let hub = LiveHub::new(&node.config.hostname, 8, false);
    let source = LiveSource::new(hub.clone());
    let live_reports = std::thread::scope(|s| {
        let feeder = s.spawn(|| replay_trace(&hub, trace, 4));
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let out = thapi::live::run_live_pipeline(source, &mut sinks, None, |_| {});
        feeder.join().unwrap();
        out
    });
    assert_eq!(hub.stats().dropped, 0);
    assert_eq!(
        live_reports.reports[0].payload(),
        pm_reports[0].payload(),
        "replayed live tally must equal post-mortem tally byte-for-byte"
    );
}
