//! §4.3 — the HIPLZ LRN tally table.
//!
//! Runs the LRN mini-app through the HIP-on-Level-Zero frontend and
//! prints the iprof tally. The shape to compare with the paper's table:
//! `hipDeviceSynchronize` near the top by total time, implemented on a
//! huge-call-count `zeEventHostSynchronize` spin (sub-µs average), and
//! `zeModuleCreate` expensive-but-once (real PJRT compile time).

use thapi::apps::hecbench;
use thapi::coordinator::{run, IprofConfig};
use thapi::device::{Node, NodeConfig};

fn main() {
    if std::env::var("THAPI_APP_SCALE").is_err() {
        std::env::set_var("THAPI_APP_SCALE", "1.0");
    }
    let node = Node::new(NodeConfig::aurora());
    let apps = hecbench::suite();
    let lrn = apps.iter().find(|a| a.name() == "lrn-hip").expect("lrn-hip in suite");

    let report = run(&node, lrn.as_ref(), &IprofConfig::default());
    let tally = report.tally().expect("trace collected");

    println!("\n=== §4.3: THAPI tally for LRN under HIPLZ (HIP on Level-Zero) ===\n");
    println!("{}", tally.render());

    // The paper's analysis points, asserted as shape checks:
    let rows = tally.host_rows();
    let find = |n: &str| rows.iter().find(|r| r.name == n);
    if let (Some(sync), Some(spin)) = (find("hipDeviceSynchronize"), find("zeEventHostSynchronize"))
    {
        println!(
            "shape check: hipDeviceSynchronize calls={} vs zeEventHostSynchronize calls={} \
             (layered spin => {}x more ze calls)",
            sync.calls,
            spin.calls,
            spin.calls / sync.calls.max(1)
        );
        assert!(
            spin.calls > sync.calls,
            "spin pattern must multiply zeEventHostSynchronize calls"
        );
        assert!(
            spin.avg_ns() < sync.avg_ns(),
            "each spin poll must be far cheaper than a full device sync"
        );
    }
    if let Some(module) = find("zeModuleCreate") {
        println!(
            "shape check: zeModuleCreate avg {} over {} call(s) (real PJRT compile)",
            thapi::analysis::tally::fmt_ns(module.avg_ns()),
            module.calls
        );
    }
}
