//! Fan-in merge cost: how the subscriber-side union merge scales with
//! publisher count.
//!
//! One recorded trace is split into K publisher wires (replay → hub →
//! publish into a Vec), then attached as a K-way fan-in and merged into
//! a tally — the whole multi-node subscriber path minus the kernel
//! socket. K = 1 is exactly the single-publisher `iprof attach` path,
//! so the K > 1 rows show the marginal cost of namespacing + merging
//! more origins over the SAME total event count (byte-identical output
//! is asserted every round). With the sharded `LiveHub`, the K reader
//! threads feed per-origin shards instead of serializing on one hub
//! mutex, so the `merge rate` column should hold (or improve) as K
//! grows — `scaling_k4_over_k1` in the JSON records exactly that.
//!
//! Results land in `BENCH_fanin_merge.json` (see `EXPERIMENTS.md`).
//! `THAPI_BENCH_QUICK=1` shrinks the workload for CI smoke runs.
//!
//! ```sh
//! cargo bench --bench fanin_merge
//! ```

use std::io::Cursor;
use std::time::Instant;
use thapi::analysis::{AnalysisSink, TallySink};
use thapi::apps::spechpc;
use thapi::bench_support::{js_num, js_str, quick_mode, BenchJson, Table};
use thapi::coordinator::{run, run_fanin, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::live::{replay_trace, LiveHub};
use thapi::remote::publish;
use thapi::tracer::btf::TraceData;
use thapi::tracer::TracingMode;

fn human_rate(per_s: f64) -> String {
    if per_s >= 1e6 {
        format!("{:.2}M/s", per_s / 1e6)
    } else if per_s >= 1e3 {
        format!("{:.1}K/s", per_s / 1e3)
    } else {
        format!("{per_s:.0}/s")
    }
}

/// Split `trace` into `k` contiguous stream subsets (in order, so the
/// fan-in concatenation reproduces the original stream layout).
fn split(trace: &TraceData, k: usize) -> Vec<TraceData> {
    let n = trace.streams.len();
    let per = n.div_ceil(k);
    (0..k)
        .map(|i| TraceData {
            metadata: trace.metadata.clone(),
            streams: trace.streams[(i * per).min(n)..((i + 1) * per).min(n)].to_vec(),
        })
        .collect()
}

fn main() {
    if std::env::var("THAPI_APP_SCALE").is_err() {
        std::env::set_var("THAPI_APP_SCALE", if quick_mode() { "0.05" } else { "0.3" });
    }
    let node = Node::new(NodeConfig::aurora());
    let apps = spechpc::suite();
    let app = &apps[0];
    let r = run(&node, app.as_ref(), &IprofConfig::paper_config(TracingMode::Full, false));
    let trace = r.trace.as_ref().unwrap();
    let events = trace.record_count();

    let pm_text = {
        let parsed = thapi::analysis::parse_trace(trace).unwrap();
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let reports = thapi::analysis::run_pipeline(&parsed, &mut sinks);
        reports[0].payload().unwrap().to_string()
    };

    let mut json = BenchJson::new("fanin_merge");
    json.meta("quick", format!("{}", quick_mode()));
    json.meta("app", js_str(app.name()));
    json.meta("events", js_num(events as f64));
    json.meta("streams", js_num(trace.streams.len() as f64));

    println!(
        "\n=== fan-in merge scaling ({}: {events} events, {} streams) ===\n",
        app.name(),
        trace.streams.len()
    );
    let mut t = Table::new(&["publishers", "publish ms", "fan-in+tally ms", "merge rate"]);
    let mut rate_by_k: Vec<(usize, f64)> = Vec::new();
    for k in [1usize, 2, 4] {
        if k > trace.streams.len() {
            println!("(skipping K={k}: only {} streams)", trace.streams.len());
            continue;
        }
        let parts = split(trace, k);

        // publish each split into its own in-memory wire (v3 batched)
        let t0 = Instant::now();
        let wires: Vec<Vec<u8>> = parts
            .iter()
            .map(|part| {
                let hub = LiveHub::new(&node.config.hostname, 4096, false);
                std::thread::scope(|s| {
                    let feeder = s.spawn(|| replay_trace(&hub, part, 64));
                    let mut buf = Vec::new();
                    publish(&hub, &mut buf).unwrap();
                    feeder.join().unwrap();
                    buf
                })
            })
            .collect();
        let publish_wall = t0.elapsed();

        // K-way fan-in: handshake, namespace, batch-decode, merge, tally —
        // K reader threads feeding the sharded hub concurrently
        let t0 = Instant::now();
        let conns: Vec<Cursor<Vec<u8>>> = wires.into_iter().map(Cursor::new).collect();
        let sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let report = run_fanin(conns, 4096, sinks, None, |_| {}, &Default::default()).unwrap();
        let fanin_wall = t0.elapsed();

        assert_eq!(report.failed_publishers(), 0);
        assert_eq!(report.server_dropped(), 0);
        assert_eq!(
            report.reports[0].payload().unwrap(),
            pm_text,
            "K={k} fan-in must stay byte-identical to whole-trace post-mortem"
        );

        let merge_rate = events as f64 / fanin_wall.as_secs_f64();
        rate_by_k.push((k, merge_rate));
        t.row(&[
            format!("{k}"),
            format!("{:.2}", publish_wall.as_secs_f64() * 1e3),
            format!("{:.2}", fanin_wall.as_secs_f64() * 1e3),
            human_rate(merge_rate),
        ]);
        json.result(&[
            ("k", js_num(k as f64)),
            ("publish_ms", js_num(publish_wall.as_secs_f64() * 1e3)),
            ("fanin_ms", js_num(fanin_wall.as_secs_f64() * 1e3)),
            ("merge_events_per_s", js_num(merge_rate)),
        ]);
    }
    println!("{}", t.render());
    println!("every row asserted byte-identical to post-mortem; drops: 0");

    let rate_at = |k: usize| rate_by_k.iter().find(|(kk, _)| *kk == k).map(|(_, r)| *r);
    if let (Some(r1), Some(r4)) = (rate_at(1), rate_at(4)) {
        println!("K=4 merge rate vs K=1: {:.2}x", r4 / r1);
        json.meta("scaling_k4_over_k1", js_num(r4 / r1));
    }
    match json.write() {
        Ok(path) => println!("results written to {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_fanin_merge.json: {e}"),
    }
}
