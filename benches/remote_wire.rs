//! Remote wire-protocol cost: THRL codec throughput and the loopback
//! end-to-end relay.
//!
//! Three measurements frame whether the network hop can keep up with the
//! tracer (paper §5 asks the same of every pipeline stage):
//!
//! 1. **encode** — frames/s and MB/s serializing a realistic Event mix;
//! 2. **decode** — the same wire parsed back;
//! 3. **loopback relay** — a recorded trace replayed through a hub,
//!    published into a Vec, attached from it, and merged into a tally:
//!    the whole remote path minus the kernel socket.
//!
//! ```sh
//! cargo bench --bench remote_wire
//! ```

use std::time::Instant;
use thapi::analysis::{AnalysisSink, TallySink};
use thapi::apps::spechpc;
use thapi::bench_support::{Stats, Table};
use thapi::coordinator::{run, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::live::{replay_trace, LiveHub};
use thapi::remote::{decode, encode, publish, Attachment, Frame, WireEvent};
use thapi::tracer::encoder::FieldValue;
use thapi::tracer::TracingMode;
use thapi::util::Rng;

fn human_rate(per_s: f64) -> String {
    if per_s >= 1e6 {
        format!("{:.2}M/s", per_s / 1e6)
    } else if per_s >= 1e3 {
        format!("{:.1}K/s", per_s / 1e3)
    } else {
        format!("{per_s:.0}/s")
    }
}

fn main() {
    let mut rng = Rng::new(0x7431_e51e);
    bench_codec(&mut rng);
    bench_loopback();
}

/// Codec throughput over a realistic Event mix (4-field events like the
/// ZE memcpy wrappers, plus beacons every 64 events like a consumer
/// round).
fn bench_codec(rng: &mut Rng) {
    const N: usize = 100_000;
    let frames: Vec<Frame> = (0..N)
        .map(|i| {
            if i % 64 == 63 {
                Frame::Beacon { stream: (i % 8) as u32, watermark: i as u64 }
            } else {
                Frame::Event {
                    stream: (i % 8) as u32,
                    event: WireEvent {
                        ts: i as u64,
                        rank: (i % 4) as u32,
                        tid: (i % 16) as u32,
                        class_id: (i % 300) as u32,
                        fields: vec![
                            FieldValue::Ptr(rng.next_u64()),
                            FieldValue::Ptr(rng.next_u64()),
                            FieldValue::U64(rng.below(1 << 20)),
                            FieldValue::U64(0),
                        ],
                    },
                }
            }
        })
        .collect();

    let mut wire = Vec::new();
    let enc = Stats::measure(2, 10, || {
        wire.clear();
        for f in &frames {
            encode(f, &mut wire);
        }
    });
    let bytes = wire.len();

    let mut decoded = 0usize;
    let dec = Stats::measure(2, 10, || {
        decoded = 0;
        let mut off = 0;
        while off < wire.len() {
            let (_, n) = decode(&wire[off..]).unwrap().unwrap();
            off += n;
            decoded += 1;
        }
    });
    assert_eq!(decoded, N);

    println!("\n=== THRL codec throughput ({N} frames, {bytes} wire bytes) ===\n");
    let mut t = Table::new(&["direction", "median wall ms", "frames", "bytes"]);
    for (name, s) in [("encode", &enc), ("decode", &dec)] {
        let secs = s.median().as_secs_f64();
        t.row(&[
            name.into(),
            format!("{:.2}", secs * 1e3),
            human_rate(N as f64 / secs),
            human_rate(bytes as f64 / secs),
        ]);
    }
    println!("{}", t.render());
}

/// End-to-end loopback: trace once, then replay → hub → publish(Vec) →
/// attach → merge → tally, asserting byte-identity with post-mortem on
/// the way.
fn bench_loopback() {
    if std::env::var("THAPI_APP_SCALE").is_err() {
        std::env::set_var("THAPI_APP_SCALE", "0.3");
    }
    let node = Node::new(NodeConfig::aurora());
    let apps = spechpc::suite();
    let app = &apps[0];
    let r = run(&node, app.as_ref(), &IprofConfig::paper_config(TracingMode::Full, false));
    let trace = r.trace.as_ref().unwrap();
    let events = trace.record_count();

    let pm_text = {
        let parsed = thapi::analysis::parse_trace(trace).unwrap();
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let reports = thapi::analysis::run_pipeline(&parsed, &mut sinks);
        reports[0].payload().unwrap().to_string()
    };

    let t0 = Instant::now();
    let hub = LiveHub::new(&node.config.hostname, 4096, false);
    let wire = std::thread::scope(|s| {
        let feeder = s.spawn(|| replay_trace(&hub, trace, 64));
        let mut buf = Vec::new();
        publish(&hub, &mut buf).unwrap();
        feeder.join().unwrap();
        buf
    });
    let publish_wall = t0.elapsed();

    let t0 = Instant::now();
    let att = Attachment::open(std::io::Cursor::new(wire.clone()), 4096).unwrap();
    let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let out = thapi::live::run_live_pipeline(att.source(), &mut sinks, None, |_| {});
    let stats = att.finish().unwrap();
    let attach_wall = t0.elapsed();

    assert_eq!(stats.server_dropped, 0);
    assert_eq!(
        out.reports[0].payload().unwrap(),
        pm_text,
        "loopback relay must stay byte-identical to post-mortem"
    );

    println!(
        "\n=== loopback relay ({}: {events} events, {} wire bytes) ===\n",
        app.name(),
        wire.len()
    );
    let mut t = Table::new(&["stage", "wall ms", "events", "wire bytes/event"]);
    t.row(&[
        "replay + publish (hub tee -> frames)".into(),
        format!("{:.2}", publish_wall.as_secs_f64() * 1e3),
        human_rate(events as f64 / publish_wall.as_secs_f64()),
        format!("{:.1}", wire.len() as f64 / events.max(1) as f64),
    ]);
    t.row(&[
        "attach + merge + tally (frames -> report)".into(),
        format!("{:.2}", attach_wall.as_secs_f64() * 1e3),
        human_rate(events as f64 / attach_wall.as_secs_f64()),
        "-".into(),
    ]);
    println!("{}", t.render());
    println!("output asserted byte-identical to post-mortem; drops: 0");
}
