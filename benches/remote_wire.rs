//! Remote wire-protocol cost: THRL codec throughput (v2 per-event vs
//! v3 batched) and the loopback end-to-end relay.
//!
//! Four measurements frame whether the network hop can keep up with the
//! tracer (paper §5 asks the same of every pipeline stage):
//!
//! 1. **encode v2 / v3** — events/s and MB/s serializing a realistic
//!    event mix: one `Event` frame per event on the v2 wire vs
//!    dictionary-compressed `EventBatch` frames on v3;
//! 2. **decode v2 / v3** — the same wires parsed back; v3 uses the
//!    stateful fast path (`decode_batch_into`) the subscriber runs;
//! 3. **loopback relay** — a recorded trace replayed through a hub,
//!    published into a Vec on each wire, attached from it, and merged
//!    into a tally: the whole remote path minus the kernel socket;
//! 4. **telemetry overhead** — the same v3 loopback with a `--telemetry`
//!    scrape endpoint being polled vs unexposed
//!    (`telemetry_overhead_pct`, budget <= 5%).
//!
//! Beacons/closes don't batch and are identical on both wires, so the
//! codec comparison uses a pure event stream; the loopback rows carry
//! the full frame mix.
//!
//! Results land in `BENCH_remote_wire.json` (see `EXPERIMENTS.md`).
//! `THAPI_BENCH_QUICK=1` shrinks the workload for CI smoke runs.
//!
//! ```sh
//! cargo bench --bench remote_wire
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;
use thapi::analysis::{AnalysisSink, TallySink};
use thapi::apps::spechpc;
use thapi::bench_support::{js_num, js_str, quick_mode, BenchJson, Stats, Table};
use thapi::coordinator::{run, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::live::{replay_trace, LiveHub};
use thapi::remote::{
    decode, decode_batch_into, encode, is_event_batch, publish_with, Attachment, BatchDict,
    BatchDictEncoder, BatchEvent, Frame, WireEvent,
};
use thapi::telemetry::{scrape, TelemetryServer};
use thapi::tracer::encoder::FieldValue;
use thapi::tracer::TracingMode;
use thapi::util::Rng;

fn human_rate(per_s: f64) -> String {
    if per_s >= 1e6 {
        format!("{:.2}M/s", per_s / 1e6)
    } else if per_s >= 1e3 {
        format!("{:.1}K/s", per_s / 1e3)
    } else {
        format!("{per_s:.0}/s")
    }
}

fn main() {
    let mut rng = Rng::new(0x7431_e51e);
    let mut json = BenchJson::new("remote_wire");
    json.meta("quick", format!("{}", quick_mode()));
    bench_codec(&mut rng, &mut json);
    bench_loopback(&mut json);
    match json.write() {
        Ok(path) => println!("\nresults written to {}", path.display()),
        Err(e) => eprintln!("\ncould not write BENCH_remote_wire.json: {e}"),
    }
}

/// One forward round's worth of events per EventBatch — the publisher
/// pump cuts batches at stream changes, so a per-stream run is the
/// realistic unit.
const BATCH: usize = 256;

/// Codec throughput over a realistic event mix: 4-field events like the
/// ZE memcpy wrappers from 16 distinct `(rank, tid, class_id)` origins
/// (the dictionary-friendly regime a real consumer round produces).
fn bench_codec(rng: &mut Rng, json: &mut BenchJson) {
    let n: usize = if quick_mode() { 20_000 } else { 200_000 };
    let (warmup, reps) = if quick_mode() { (1, 3) } else { (2, 10) };
    let raw: Vec<(u64, u32, u32, u32, Vec<FieldValue>)> = (0..n)
        .map(|i| {
            let fields = vec![
                FieldValue::Ptr(rng.next_u64()),
                FieldValue::Ptr(rng.next_u64()),
                FieldValue::U64(rng.below(1 << 20)),
                FieldValue::U64(0),
            ];
            // small monotone-ish ts steps: the delta-varint sweet spot
            ((i as u64) * 30 + rng.below(10), (i % 4) as u32, (i % 16) as u32, (i % 12) as u32, fields)
        })
        .collect();

    // v2: one Event frame per event
    let v2_frames: Vec<Frame> = raw
        .iter()
        .map(|(ts, rank, tid, class_id, fields)| Frame::Event {
            stream: 0,
            event: WireEvent {
                ts: *ts,
                rank: *rank,
                tid: *tid,
                class_id: *class_id,
                fields: fields.clone(),
            },
        })
        .collect();

    // v3: EventBatch frames of BATCH events, keys through one connection
    // dictionary (the same assignment the publisher pump performs)
    let mut dict_enc = BatchDictEncoder::new();
    let v3_frames: Vec<Frame> = raw
        .chunks(BATCH)
        .map(|chunk| Frame::EventBatch {
            stream: 0,
            events: chunk
                .iter()
                .map(|(ts, rank, tid, class_id, fields)| BatchEvent {
                    ts: *ts,
                    key: dict_enc.key_for(*rank, *tid, *class_id),
                    fields: fields.clone(),
                })
                .collect(),
        })
        .collect();

    let mut wire_v2 = Vec::new();
    let enc_v2 = Stats::measure(warmup, reps, || {
        wire_v2.clear();
        for f in &v2_frames {
            encode(f, &mut wire_v2);
        }
    });
    let mut wire_v3 = Vec::new();
    let enc_v3 = Stats::measure(warmup, reps, || {
        wire_v3.clear();
        for f in &v3_frames {
            encode(f, &mut wire_v3);
        }
    });

    let mut decoded = 0usize;
    let dec_v2 = Stats::measure(warmup, reps, || {
        decoded = 0;
        let mut off = 0;
        while off < wire_v2.len() {
            let (_, consumed) = decode(&wire_v2[off..]).unwrap().unwrap();
            off += consumed;
            decoded += 1;
        }
    });
    assert_eq!(decoded, n);

    // v3 decode through the stateful fast path the subscriber runs:
    // frame split + decode_batch_into, fields landing in the reused
    // scratch buffer
    let dec_v3 = Stats::measure(warmup, reps, || {
        decoded = 0;
        let mut dict = BatchDict::new();
        let mut off = 0;
        while off < wire_v3.len() {
            let len = u32::from_le_bytes(wire_v3[off..off + 4].try_into().unwrap()) as usize;
            let body = &wire_v3[off + 4..off + 4 + len];
            assert!(is_event_batch(body));
            let (_, events) = decode_batch_into(body, &mut dict, |_, _, _, _, _| ()).unwrap();
            decoded += events;
            off += 4 + len;
        }
    });
    assert_eq!(decoded, n);

    let rate = |s: &Stats| n as f64 / s.median().as_secs_f64();
    let enc_speedup = rate(&enc_v3) / rate(&enc_v2);
    let dec_speedup = rate(&dec_v3) / rate(&dec_v2);

    println!(
        "\n=== THRL codec throughput ({n} events; v2 {} B, v3 {} B on the wire) ===\n",
        wire_v2.len(),
        wire_v3.len()
    );
    let mut t = Table::new(&["direction", "median wall ms", "events", "bytes/event"]);
    let rows: [(&str, &Stats, usize); 4] = [
        ("encode v2 per-event", &enc_v2, wire_v2.len()),
        ("encode v3 batched", &enc_v3, wire_v3.len()),
        ("decode v2 per-event", &dec_v2, wire_v2.len()),
        ("decode v3 batched", &dec_v3, wire_v3.len()),
    ];
    for (name, s, bytes) in rows {
        let secs = s.median().as_secs_f64();
        t.row(&[
            name.into(),
            format!("{:.2}", secs * 1e3),
            human_rate(n as f64 / secs),
            format!("{:.1}", bytes as f64 / n as f64),
        ]);
    }
    println!("{}", t.render());
    println!(
        "v3 speedup: encode {enc_speedup:.2}x, decode {dec_speedup:.2}x, \
         wire size {:.2}x smaller (target: >= 3x codec throughput)",
        wire_v2.len() as f64 / wire_v3.len() as f64
    );

    json.meta("codec_events", js_num(n as f64));
    json.meta("batch_size", js_num(BATCH as f64));
    json.meta("encode_speedup_v3_over_v2", js_num(enc_speedup));
    json.meta("decode_speedup_v3_over_v2", js_num(dec_speedup));
    for (name, s, bytes) in [
        ("encode_v2", &enc_v2, wire_v2.len()),
        ("encode_v3", &enc_v3, wire_v3.len()),
        ("decode_v2", &dec_v2, wire_v2.len()),
        ("decode_v3", &dec_v3, wire_v3.len()),
    ] {
        let secs = s.median().as_secs_f64();
        json.result(&[
            ("name", js_str(name)),
            ("events_per_s", js_num(n as f64 / secs)),
            ("mb_per_s", js_num(bytes as f64 / secs / 1e6)),
            ("bytes_per_event", js_num(bytes as f64 / n as f64)),
            ("median_ms", js_num(secs * 1e3)),
        ]);
    }
}

/// End-to-end loopback on each wire: trace once, then replay → hub →
/// publish(Vec) → attach → merge → tally, asserting byte-identity with
/// post-mortem on the way.
fn bench_loopback(json: &mut BenchJson) {
    if std::env::var("THAPI_APP_SCALE").is_err() {
        std::env::set_var("THAPI_APP_SCALE", if quick_mode() { "0.05" } else { "0.3" });
    }
    let node = Node::new(NodeConfig::aurora());
    let apps = spechpc::suite();
    let app = &apps[0];
    let r = run(&node, app.as_ref(), &IprofConfig::paper_config(TracingMode::Full, false));
    let trace = r.trace.as_ref().unwrap();
    let events = trace.record_count();

    let pm_text = {
        let parsed = thapi::analysis::parse_trace(trace).unwrap();
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let reports = thapi::analysis::run_pipeline(&parsed, &mut sinks);
        reports[0].payload().unwrap().to_string()
    };

    println!("\n=== loopback relay ({}: {events} events) ===\n", app.name());
    let mut t = Table::new(&["wire", "publish ms", "attach+merge ms", "wire bytes/event"]);
    json.meta("loopback_app", js_str(app.name()));
    json.meta("loopback_events", js_num(events as f64));
    for wire_version in [2u32, 3] {
        let t0 = Instant::now();
        let hub = LiveHub::new(&node.config.hostname, 4096, false);
        let wire = std::thread::scope(|s| {
            let feeder = s.spawn(|| replay_trace(&hub, trace, 64));
            let mut buf = Vec::new();
            publish_with(&hub, &mut buf, wire_version).unwrap();
            feeder.join().unwrap();
            buf
        });
        let publish_wall = t0.elapsed();

        let t0 = Instant::now();
        let att = Attachment::open(std::io::Cursor::new(wire.clone()), 4096).unwrap();
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let out = thapi::live::run_live_pipeline(att.source(), &mut sinks, None, |_| {});
        let stats = att.finish().unwrap();
        let attach_wall = t0.elapsed();

        assert_eq!(stats.server_dropped, 0);
        assert_eq!(stats.wire_version, wire_version);
        assert_eq!(
            out.reports[0].payload().unwrap(),
            pm_text,
            "loopback relay (wire v{wire_version}) must stay byte-identical to post-mortem"
        );

        t.row(&[
            format!("v{wire_version}"),
            format!("{:.2}", publish_wall.as_secs_f64() * 1e3),
            format!("{:.2}", attach_wall.as_secs_f64() * 1e3),
            format!("{:.1}", wire.len() as f64 / events.max(1) as f64),
        ]);
        json.result(&[
            ("name", js_str(&format!("loopback_v{wire_version}"))),
            ("publish_ms", js_num(publish_wall.as_secs_f64() * 1e3)),
            ("attach_ms", js_num(attach_wall.as_secs_f64() * 1e3)),
            ("wire_bytes", js_num(wire.len() as f64)),
            ("bytes_per_event", js_num(wire.len() as f64 / events.max(1) as f64)),
        ]);
    }
    println!("{}", t.render());
    println!("both wires asserted byte-identical to post-mortem; drops: 0");

    // ── telemetry exposure overhead ────────────────────────────────
    // The registry's counters always run (they ARE the accounting); what
    // can be toggled is the exposure. Re-run the v3 loopback with a
    // scrape endpoint bound on the subscriber's registry and an
    // aggressive poller hitting it (every ~5 ms — far hotter than any
    // real Prometheus job), vs no endpoint at all. The delta is the
    // price of being watched; target <= 5%.
    let (warmup, reps) = if quick_mode() { (1, 3) } else { (2, 7) };
    let loopback_v3 = |expose: bool| {
        let hub = LiveHub::new(&node.config.hostname, 4096, false);
        let wire = std::thread::scope(|s| {
            let feeder = s.spawn(|| replay_trace(&hub, trace, 64));
            let mut buf = Vec::new();
            publish_with(&hub, &mut buf, 3).unwrap();
            feeder.join().unwrap();
            buf
        });
        let att = Attachment::open(std::io::Cursor::new(wire), 4096).unwrap();
        let source = att.source();
        let endpoint = if expose {
            let registry = source.hub().telemetry().clone();
            let server = TelemetryServer::bind("127.0.0.1:0", registry).unwrap();
            let addr = server.local_addr().to_string();
            let stop = Arc::new(AtomicBool::new(false));
            let flag = stop.clone();
            let poller = std::thread::spawn(move || {
                while !flag.load(Ordering::Relaxed) {
                    let _ = scrape(&addr);
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            });
            Some((server, stop, poller))
        } else {
            None
        };
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let out = thapi::live::run_live_pipeline(source, &mut sinks, None, |_| {});
        let stats = att.finish().unwrap();
        if let Some((server, stop, poller)) = endpoint {
            stop.store(true, Ordering::Relaxed);
            poller.join().unwrap();
            server.shutdown();
        }
        assert_eq!(stats.server_dropped, 0);
        assert_eq!(out.reports[0].payload().unwrap(), pm_text);
    };
    let off = Stats::measure(warmup, reps, || loopback_v3(false));
    let on = Stats::measure(warmup, reps, || loopback_v3(true));
    let (off_ms, on_ms) =
        (off.median().as_secs_f64() * 1e3, on.median().as_secs_f64() * 1e3);
    let overhead_pct = (on_ms / off_ms - 1.0) * 100.0;
    println!(
        "telemetry exposure overhead (v3 loopback, ~5 ms scrape poller): \
         off {off_ms:.2} ms, on {on_ms:.2} ms => {overhead_pct:+.2}% (target <= 5%)"
    );
    json.meta("telemetry_overhead_pct", js_num(overhead_pct));
    for (name, ms) in [("loopback_v3_tele_off", off_ms), ("loopback_v3_tele_on", on_ms)] {
        json.result(&[
            ("name", js_str(name)),
            ("median_ms", js_num(ms)),
            ("events_per_s", js_num(events as f64 / (ms / 1e3))),
        ]);
    }
}
