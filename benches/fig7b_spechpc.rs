//! Fig. 7b — SPEChpc 2021 tracing overhead (default mode), Aurora vs
//! Polaris node configurations.
//!
//! Paper reference: mean default-mode overhead 4.35 % on Aurora and
//! 5.14 % on Polaris; no benchmark exceeding 10 %. Our Aurora node runs
//! 6 ranks on 6 two-tile ZE GPUs; Polaris runs 4 ranks on 4 CUDA-labelled
//! GPUs (the MPI+OMP offload path is identical; the node config differs
//! in GPU count/tiling/telemetry, as in Table 1).
//!
//! Env knobs: `THAPI_BENCH_REPS` (default 3), `THAPI_APP_SCALE`.

use thapi::apps::spechpc;
use thapi::bench_support::{mean_of, Table};
use thapi::coordinator::{overhead_pct, run, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::tracer::{SinkKind, TracingMode};

fn main() {
    let reps: usize = std::env::var("THAPI_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    if std::env::var("THAPI_APP_SCALE").is_err() {
        std::env::set_var("THAPI_APP_SCALE", "0.5");
    }
    let apps = spechpc::suite();
    let mut config = IprofConfig::paper_config(TracingMode::Default, false);
    config.sink = SinkKind::Null;

    let mut table = Table::new(&["benchmark", "aurora %", "polaris %"]);
    let mut aurora_all = Vec::new();
    let mut polaris_all = Vec::new();

    for app in &apps {
        let mut cells = vec![app.name().to_string()];
        for (node_cfg, acc) in [
            (NodeConfig::aurora(), &mut aurora_all),
            (NodeConfig::polaris(), &mut polaris_all),
        ] {
            let node = Node::new(node_cfg);
            let _ = run(&node, app.as_ref(), &IprofConfig::baseline()); // warmup
            let base = (0..reps)
                .map(|_| run(&node, app.as_ref(), &IprofConfig::baseline()).wall)
                .min()
                .unwrap();
            let traced = (0..reps)
                .map(|_| run(&node, app.as_ref(), &config).wall)
                .min()
                .unwrap();
            let pct = overhead_pct(base, traced);
            acc.push(pct);
            cells.push(format!("{pct:+.2}%"));
        }
        table.row(&cells);
        eprintln!("done {}", app.name());
    }

    println!("\n=== Fig 7b: SPEChpc default-mode overhead, Aurora vs Polaris ===\n");
    println!("{}", table.render());
    println!(
        "mean: aurora {:.2}%  polaris {:.2}%   (paper: 4.35% / 5.14%, max < 10%)",
        mean_of(&aurora_all),
        mean_of(&polaris_all)
    );
}
