//! Fig. 8a/8b — disk-space requirement of the traces by tracing mode.
//!
//! Runs the SPEChpc-like suite under all six configurations with an
//! in-memory sink and reports the BTF trace size per benchmark (8a) and
//! the per-mode size normalized to T-full (8b). Paper reference: on
//! average default needs < 20 % and minimal < 17 % of the full-mode
//! space; 534.hpgmgfv and 521.miniswp show the largest min↔full spread.

use thapi::apps::spechpc;
use thapi::bench_support::{mean_of, Table};
use thapi::coordinator::{run, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::tracer::TracingMode;

fn human(bytes: u64) -> String {
    let b = bytes as f64;
    if b >= 1e9 {
        format!("{:.2}GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2}MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1}KB", b / 1e3)
    } else {
        format!("{bytes}B")
    }
}

fn main() {
    if std::env::var("THAPI_APP_SCALE").is_err() {
        std::env::set_var("THAPI_APP_SCALE", "0.5");
    }
    let node = Node::new(NodeConfig::aurora());
    let apps = spechpc::suite();

    let configs: Vec<IprofConfig> = [
        (TracingMode::Minimal, false),
        (TracingMode::Default, false),
        (TracingMode::Full, false),
        (TracingMode::Minimal, true),
        (TracingMode::Default, true),
        (TracingMode::Full, true),
    ]
    .iter()
    .map(|(m, s)| IprofConfig::paper_config(*m, *s))
    .collect();
    let labels: Vec<String> = configs.iter().map(|c| c.label()).collect();

    let mut table = Table::new(&{
        let mut h = vec!["benchmark"];
        h.extend(labels.iter().map(|s| s.as_str()));
        h
    });
    // sizes[config][app]
    let mut sizes: Vec<Vec<u64>> = vec![Vec::new(); configs.len()];

    for app in &apps {
        let _ = run(&node, app.as_ref(), &IprofConfig::baseline()); // warmup
        let mut cells = vec![app.name().to_string()];
        for (ci, c) in configs.iter().enumerate() {
            let r = run(&node, app.as_ref(), c);
            let bytes = r.trace_bytes();
            sizes[ci].push(bytes);
            cells.push(human(bytes));
        }
        table.row(&cells);
        eprintln!("done {}", app.name());
    }

    println!("\n=== Fig 8a: trace space per benchmark and mode ===\n");
    println!("{}", table.render());

    // Fig 8b: normalized to T-full per app, averaged
    let full_idx = labels.iter().position(|l| l == "T-full").unwrap();
    let mut norm = Table::new(&["config", "avg size vs T-full"]);
    for (ci, label) in labels.iter().enumerate() {
        let ratios: Vec<f64> = sizes[ci]
            .iter()
            .zip(&sizes[full_idx])
            .map(|(s, f)| *s as f64 / (*f).max(1) as f64 * 100.0)
            .collect();
        norm.row(&[label.clone(), format!("{:.1}%", mean_of(&ratios))]);
    }
    println!("=== Fig 8b: space normalized to T-full ===\n");
    println!("{}", norm.render());
    println!("paper reference: default < 20% and minimal < 17% of full-mode space.");
}
