//! Fig. 8a/8b — disk-space requirement of the traces by tracing mode.
//!
//! Runs the SPEChpc-like suite under all six configurations with an
//! in-memory sink and reports the BTF trace size per benchmark (8a) and
//! the per-mode size normalized to T-full (8b). Paper reference: on
//! average default needs < 20 % and minimal < 17 % of the full-mode
//! space; 534.hpgmgfv and 521.miniswp show the largest min↔full spread.

use std::time::Instant;
use thapi::analysis::{self, AnalysisSink, TallySink, TimelineSink, ValidateSink};
use thapi::apps::spechpc;
use thapi::bench_support::{alloc_track, mean_of, Table};
use thapi::coordinator::{run, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::live::{replay_trace, LiveHub, LiveSource};
use thapi::tracer::TracingMode;

// Exact heap accounting for the streaming-vs-materialized comparison.
#[global_allocator]
static ALLOC: alloc_track::CountingAlloc = alloc_track::CountingAlloc;

fn human(bytes: u64) -> String {
    let b = bytes as f64;
    if b >= 1e9 {
        format!("{:.2}GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2}MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1}KB", b / 1e3)
    } else {
        format!("{bytes}B")
    }
}

fn main() {
    if std::env::var("THAPI_APP_SCALE").is_err() {
        std::env::set_var("THAPI_APP_SCALE", "0.5");
    }
    let node = Node::new(NodeConfig::aurora());
    let apps = spechpc::suite();

    let configs: Vec<IprofConfig> = [
        (TracingMode::Minimal, false),
        (TracingMode::Default, false),
        (TracingMode::Full, false),
        (TracingMode::Minimal, true),
        (TracingMode::Default, true),
        (TracingMode::Full, true),
    ]
    .iter()
    .map(|(m, s)| IprofConfig::paper_config(*m, *s))
    .collect();
    let labels: Vec<String> = configs.iter().map(|c| c.label()).collect();

    let mut table = Table::new(&{
        let mut h = vec!["benchmark"];
        h.extend(labels.iter().map(|s| s.as_str()));
        h
    });
    // sizes[config][app]
    let mut sizes: Vec<Vec<u64>> = vec![Vec::new(); configs.len()];

    for app in &apps {
        let _ = run(&node, app.as_ref(), &IprofConfig::baseline()); // warmup
        let mut cells = vec![app.name().to_string()];
        for (ci, c) in configs.iter().enumerate() {
            let r = run(&node, app.as_ref(), c);
            let bytes = r.trace_bytes();
            sizes[ci].push(bytes);
            cells.push(human(bytes));
        }
        table.row(&cells);
        eprintln!("done {}", app.name());
    }

    println!("\n=== Fig 8a: trace space per benchmark and mode ===\n");
    println!("{}", table.render());

    // Fig 8b: normalized to T-full per app, averaged
    let full_idx = labels.iter().position(|l| l == "T-full").unwrap();
    let mut norm = Table::new(&["config", "avg size vs T-full"]);
    for (ci, label) in labels.iter().enumerate() {
        let ratios: Vec<f64> = sizes[ci]
            .iter()
            .zip(&sizes[full_idx])
            .map(|(s, f)| *s as f64 / (*f).max(1) as f64 * 100.0)
            .collect();
        norm.row(&[label.clone(), format!("{:.1}%", mean_of(&ratios))]);
    }
    println!("=== Fig 8b: space normalized to T-full ===\n");
    println!("{}", norm.render());
    println!("paper reference: default < 20% and minimal < 17% of full-mode space.");

    analysis_phase_memory(&node);
    live_analysis_memory(&node);
}

/// Analysis-phase cost: the seed-style materialized two-pass path (clone
/// every event into an owned merged vector, build a full span vector,
/// then run each eager renderer over those slices) vs the streaming
/// single-pass graph driving tally+timeline+validate at once. Tracks
/// wall clock and peak live heap over the same T-full trace. (The
/// `mux`/`pair_intervals` shims are deleted; the baseline reconstructs
/// the same materialization from the streaming primitives.)
fn analysis_phase_memory(node: &std::sync::Arc<thapi::device::Node>) {
    let apps = spechpc::suite();
    let app = &apps[0];
    let r = run(node, app.as_ref(), &IprofConfig::paper_config(TracingMode::Full, false));
    let trace = r.trace.as_ref().unwrap();
    let parsed = analysis::parse_trace(trace).unwrap();
    let events = parsed.event_count();

    // materialized baseline: every sink over owned vectors. One merge
    // only (like the seed's mux + pair_intervals shape): the span vector
    // is paired from the already-merged `msgs`, not by re-merging.
    let live0 = alloc_track::live_bytes();
    alloc_track::reset_peak();
    let t0 = Instant::now();
    let msgs: Vec<analysis::EventMsg> =
        analysis::MessageSource::new(&parsed).cloned().collect();
    let mut tracker = analysis::IntervalTracker::new();
    let mut intervals = Vec::new();
    for m in &msgs {
        tracker.push(m, |iv| intervals.push(iv));
    }
    tracker.finish(|iv| intervals.push(iv));
    intervals.sort_by_key(|iv| iv.start);
    let tally_text = analysis::Tally::build(&intervals, &msgs).render();
    let timeline_text = analysis::timeline_json(&intervals, &msgs);
    let findings = analysis::validate(&msgs);
    let mat_wall = t0.elapsed();
    let mat_peak = alloc_track::peak_bytes().saturating_sub(live0);
    let mat_out = (tally_text.len(), timeline_text.len(), findings.len());
    drop((msgs, intervals, tally_text, timeline_text, findings));

    // streaming graph: one pass, zero-copy source, three sinks
    let live0 = alloc_track::live_bytes();
    alloc_track::reset_peak();
    let t0 = Instant::now();
    let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![
        Box::new(TallySink::new()),
        Box::new(TimelineSink::new()),
        Box::new(ValidateSink::new()),
    ];
    let reports = analysis::run_pipeline(&parsed, &mut sinks);
    let stream_wall = t0.elapsed();
    let stream_peak = alloc_track::peak_bytes().saturating_sub(live0);
    let stream_out: usize = reports.iter().filter_map(|r| r.payload()).map(str::len).sum();
    drop(reports);

    println!(
        "\n=== analysis phase: streaming single-pass vs materialized two-pass ({}: {} events) ===\n",
        app.name(),
        events
    );
    let mut t = Table::new(&["pipeline", "wall ms", "peak heap", "outputs"]);
    t.row(&[
        "materialized (owned merge + span vec + 3 rescans)".into(),
        format!("{:.2}", mat_wall.as_secs_f64() * 1e3),
        human(mat_peak as u64),
        format!("{}B tally, {}B timeline, {} findings", mat_out.0, mat_out.1, mat_out.2),
    ]);
    t.row(&[
        "streaming (1 pass, 3 sinks)".into(),
        format!("{:.2}", stream_wall.as_secs_f64() * 1e3),
        human(stream_peak as u64),
        format!("{stream_out}B total"),
    ]);
    println!("{}", t.render());
    println!(
        "streaming peak is {:.1}% of materialized peak",
        stream_peak as f64 * 100.0 / (mat_peak as f64).max(1.0)
    );
}

/// Live vs post-mortem analysis: peak heap and event staleness.
///
/// Post-mortem must hold the decoded trace (`parse_trace` + merge state)
/// before the first sink sees a message; live analysis streams the same
/// records through bounded channels, so its peak is O(streams × channel
/// depth) — independent of trace length. Both paths run the tally sink
/// over the SAME recorded trace (live via `replay_trace`, which feeds
/// the channels losslessly with beacons, exactly like the consumer
/// thread does on-line), so outputs are byte-identical and the memory
/// difference is purely architectural. Two channel depths show the live
/// peak tracking depth, not trace size.
fn live_analysis_memory(node: &std::sync::Arc<thapi::device::Node>) {
    let apps = spechpc::suite();
    let app = &apps[0];
    let r = run(node, app.as_ref(), &IprofConfig::paper_config(TracingMode::Full, false));
    let trace = r.trace.as_ref().unwrap();
    let events = trace.record_count();

    // post-mortem: decode-everything-then-analyze (parse included in the
    // measured region — live mode never pays it at all)
    let live0 = alloc_track::live_bytes();
    alloc_track::reset_peak();
    let t0 = Instant::now();
    let parsed = analysis::parse_trace(trace).unwrap();
    let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
    let pm_reports = analysis::run_pipeline(&parsed, &mut sinks);
    let pm_wall = t0.elapsed();
    let pm_peak = alloc_track::peak_bytes().saturating_sub(live0);
    let pm_text = pm_reports[0].payload().unwrap().to_string();
    drop((parsed, pm_reports, sinks));

    let mut t = Table::new(&["pipeline", "wall ms", "peak heap", "staleness mean/max"]);
    t.row(&[
        "post-mortem (parse + 1 pass)".into(),
        format!("{:.2}", pm_wall.as_secs_f64() * 1e3),
        human(pm_peak as u64),
        "whole run (analysis starts at exit)".into(),
    ]);

    let mut live_peaks = Vec::new();
    for depth in [256usize, 4096] {
        let live0 = alloc_track::live_bytes();
        alloc_track::reset_peak();
        let t0 = Instant::now();
        let hub = LiveHub::new(&node.config.hostname, depth, false);
        let source = LiveSource::new(hub.clone());
        let out = std::thread::scope(|s| {
            let feeder = s.spawn(|| replay_trace(&hub, trace, 64));
            let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
            let out = thapi::live::run_live_pipeline(source, &mut sinks, None, |_| {});
            feeder.join().unwrap();
            out
        });
        let live_wall = t0.elapsed();
        let live_peak = alloc_track::peak_bytes().saturating_sub(live0);
        live_peaks.push(live_peak);
        assert_eq!(hub.stats().dropped, 0, "replay is lossless");
        assert_eq!(
            out.reports[0].payload().unwrap(),
            pm_text,
            "live output must be byte-identical to post-mortem"
        );
        t.row(&[
            format!("live (bounded channels, depth {depth})"),
            format!("{:.2}", live_wall.as_secs_f64() * 1e3),
            human(live_peak as u64),
            format!(
                "{:.2}ms / {:.2}ms",
                out.latency.mean().as_secs_f64() * 1e3,
                out.latency.max.as_secs_f64() * 1e3
            ),
        ]);
    }

    println!(
        "\n=== live vs post-mortem analysis ({}: {} events, T-full) ===\n",
        app.name(),
        events
    );
    println!("{}", t.render());
    println!(
        "live peak is {:.1}% (depth 256) / {:.1}% (depth 4096) of the post-mortem peak;",
        live_peaks[0] as f64 * 100.0 / (pm_peak as f64).max(1.0),
        live_peaks[1] as f64 * 100.0 / (pm_peak as f64).max(1.0),
    );
    println!("live analysis memory is bounded by channel depth, not by trace size.");
}
