//! Fig. 7a — runtime overhead of THAPI across tracing modes, HeCBench.
//!
//! Runs every HeCBench-like mini-app under the six §5.2 configurations
//! (T-min/T-default/T-full, TS-min/TS-default/TS-full) against an
//! untraced baseline, and prints the per-config overhead distribution
//! (mean and median — the paper reports T-default mean 5.36 %, median
//! 1.99 %; sampling adds ≈ +1 %; T-min is slightly *higher* overhead than
//! T-default despite tracking fewer events).
//!
//! Env knobs: `THAPI_BENCH_REPS` (default 3), `THAPI_APP_SCALE`.

use std::sync::Arc;
use thapi::apps::hecbench;
use thapi::bench_support::{mean_of, median_of, Table};
use thapi::coordinator::{overhead_pct, run, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::tracer::{SinkKind, TracingMode};

fn main() {
    let reps: usize = std::env::var("THAPI_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    if std::env::var("THAPI_APP_SCALE").is_err() {
        std::env::set_var("THAPI_APP_SCALE", "0.5");
    }
    let node = Node::new(NodeConfig::test_small());
    let apps = hecbench::suite();

    let configs: Vec<IprofConfig> = [
        (TracingMode::Minimal, false),
        (TracingMode::Default, false),
        (TracingMode::Full, false),
        (TracingMode::Minimal, true),
        (TracingMode::Default, true),
        (TracingMode::Full, true),
    ]
    .iter()
    .map(|(m, s)| {
        let mut c = IprofConfig::paper_config(*m, *s);
        c.sink = SinkKind::Null; // pure runtime overhead, like the paper's %
        c
    })
    .collect();
    let labels: Vec<String> = configs.iter().map(|c| c.label()).collect();

    // per config, per app: overhead %
    let mut overheads: Vec<Vec<f64>> = vec![Vec::new(); configs.len()];
    let mut table = Table::new(&{
        let mut h = vec!["app"];
        h.extend(labels.iter().map(|s| s.as_str()));
        h
    });

    for app in &apps {
        // warmup: compile caches, page faults
        let _ = run(&node, app.as_ref(), &IprofConfig::baseline());
        // baseline: best of reps (noise-robust denominator)
        let base = (0..reps)
            .map(|_| run(&node, app.as_ref(), &IprofConfig::baseline()).wall)
            .min()
            .unwrap();
        let mut cells = vec![app.name().to_string()];
        for (ci, c) in configs.iter().enumerate() {
            let traced = (0..reps)
                .map(|_| run(&node, app.as_ref(), c).wall)
                .min()
                .unwrap();
            let pct = overhead_pct(base, traced);
            overheads[ci].push(pct);
            cells.push(format!("{pct:+.2}%"));
        }
        table.row(&cells);
        eprintln!("done {}", app.name());
    }

    println!("\n=== Fig 7a: HeCBench tracing overhead by configuration ===\n");
    println!("{}", table.render());

    let mut summary = Table::new(&["config", "mean %", "median %", "max %"]);
    for (ci, label) in labels.iter().enumerate() {
        let v = &overheads[ci];
        summary.row(&[
            label.clone(),
            format!("{:.2}", mean_of(v)),
            format!("{:.2}", median_of(v)),
            format!("{:.2}", v.iter().cloned().fold(f64::MIN, f64::max)),
        ]);
    }
    println!("{}", summary.render());
    println!(
        "paper reference: T-default mean 5.36%, median 1.99%; sampling ≈ +1%; \
         T-min slightly above T-default."
    );
}
