//! E12 — §3.7 aggregation scaling: composite-profile merge up to 512
//! nodes × 6 ranks, reporting merge latency and per-hop aggregate sizes
//! ("typically in the range of kilobytes").

use std::time::Instant;
use thapi::aggregate::{aggregate_tree, RankAggregate};
use thapi::analysis::{Tally, TallyRow};
use thapi::bench_support::Table;
use thapi::util::Rng;

/// A realistic per-rank tally: ~40 distinct API rows across backends.
fn synthetic_tally(rng: &mut Rng, rank: u32) -> Tally {
    let mut t = Tally::default();
    let fns = [
        ("ZE", "zeCommandListAppendMemoryCopy"),
        ("ZE", "zeCommandListAppendLaunchKernel"),
        ("ZE", "zeCommandQueueSynchronize"),
        ("ZE", "zeEventHostSynchronize"),
        ("ZE", "zeModuleCreate"),
        ("HIP", "hipMemcpy"),
        ("HIP", "hipDeviceSynchronize"),
        ("HIP", "hipLaunchKernel"),
        ("OMP", "omp_target_memcpy"),
        ("OMP", "ompt_target_submit"),
        ("MPI", "MPI_Send"),
        ("MPI", "MPI_Recv"),
        ("MPI", "MPI_Allreduce"),
        ("CUDA", "cuLaunchKernel"),
        ("CUDA", "cuMemcpyHtoD"),
    ];
    for (api, name) in fns {
        for v in 0..3 {
            let calls = 1 + rng.below(10_000);
            let avg = 200 + rng.below(1_000_000);
            t.host.insert(
                (api.to_string(), format!("{name}{}", if v == 0 { String::new() } else { format!("_v{v}") })),
                TallyRow {
                    name: format!("{name}{}", if v == 0 { String::new() } else { format!("_v{v}") }),
                    api: api.to_string(),
                    time_ns: calls * avg,
                    calls,
                    min_ns: avg / 2,
                    max_ns: avg * 10,
                },
            );
        }
    }
    t.hostnames.insert(format!("node{}", rank / 6));
    t.processes.insert(rank);
    t.threads.insert((rank, rank));
    t
}

/// Aggregate-only mode on a real trace: one traced run per rank, each
/// reduced to its kilobyte tally straight from the stream (lazy muxing +
/// incremental pairing — the per-rank trace is never materialized as a
/// merged `Vec<EventMsg>`).
fn real_trace_rank_reduction() {
    use thapi::apps::hecbench;
    use thapi::coordinator::{run, IprofConfig};
    use thapi::device::{Node, NodeConfig};

    if std::env::var("THAPI_APP_SCALE").is_err() {
        std::env::set_var("THAPI_APP_SCALE", "0.1");
    }
    let node = Node::new(NodeConfig::test_small());
    let apps = hecbench::suite();
    let app = apps.iter().find(|a| a.name() == "saxpy-ze").unwrap();

    println!("=== §3.7 aggregate-only: per-rank reduction from real trace streams ===\n");
    let mut table = Table::new(&["rank", "trace B", "reduce ms", "aggregate B"]);
    let mut aggs = Vec::new();
    for rank in 0..3u32 {
        let r = run(&node, app.as_ref(), &IprofConfig::default());
        let trace = r.trace.as_ref().unwrap();
        let t0 = Instant::now();
        let agg = RankAggregate::from_trace(0, rank, trace).unwrap();
        let reduce = t0.elapsed();
        table.row(&[
            rank.to_string(),
            trace.size_bytes().to_string(),
            format!("{:.2}", reduce.as_secs_f64() * 1e3),
            agg.size_bytes().to_string(),
        ]);
        aggs.push(agg);
    }
    println!("{}", table.render());
    let merged = thapi::aggregate::local_master_merge(0, &aggs).unwrap();
    println!("local-master aggregate: {} bytes\n", merged.size_bytes());
}

fn main() {
    real_trace_rank_reduction();
    println!("\n=== E12: §3.7 two-level aggregation scaling ===\n");
    let mut table = Table::new(&["nodes", "ranks", "merge ms", "bytes moved", "per-hop B"]);
    for nodes in [8u32, 32, 128, 512] {
        let ranks_per_node = 6u32;
        let mut rng = Rng::new(42);
        let per_rank: Vec<(u32, u32, Tally)> = (0..nodes)
            .flat_map(|n| {
                (0..ranks_per_node)
                    .map(|r| (n, r, synthetic_tally(&mut Rng::new(rng.next_u64()), n * ranks_per_node + r)))
                    .collect::<Vec<_>>()
            })
            .collect();
        let t0 = Instant::now();
        let (composite, bytes) = aggregate_tree(&per_rank).unwrap();
        let elapsed = t0.elapsed();
        let hops = nodes * ranks_per_node + nodes;
        table.row(&[
            nodes.to_string(),
            (nodes * ranks_per_node).to_string(),
            format!("{:.2}", elapsed.as_secs_f64() * 1e3),
            bytes.to_string(),
            (bytes as u32 / hops).to_string(),
        ]);
        assert_eq!(composite.processes.len(), (nodes * ranks_per_node) as usize);
    }
    println!("{}", table.render());
    println!("paper reference: aggregates are kilobytes; scaled to 512 nodes in production.");
}
