//! E11 — tracepoint cost microbenchmark (paper §3.1: LTTng tracepoints
//! cost "in the order of nanoseconds").
//!
//! Measures the per-event cost of the emit hot path in four states:
//! no session installed, class disabled by mode, enabled with a small
//! payload, and enabled with the full memcpy-entry payload. Also reports
//! sustained throughput into the ring buffer with a Null-sink consumer.

use std::time::Instant;
use thapi::bench_support::Table;
use thapi::model::class_by_name;
use thapi::tracer::{
    emit, install_session, uninstall_session, SessionConfig, SinkKind, TracingMode,
};

fn per_event_ns<F: FnMut()>(n: u64, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..n {
        f();
    }
    t0.elapsed().as_nanos() as f64 / n as f64
}

fn main() {
    let n = 2_000_000u64;
    let small = class_by_name("lttng_ust_ze:zeInit_entry").unwrap();
    let memcpy = class_by_name("lttng_ust_ze:zeCommandListAppendMemoryCopy_entry").unwrap();
    let polling = class_by_name("lttng_ust_ze:zeEventQueryStatus_entry").unwrap();

    let mut table = Table::new(&["state", "ns/event"]);

    // 1. no session
    let ns = per_event_ns(n, || {
        emit(small, |e| {
            e.u64(0);
        });
    });
    table.row(&["no session".into(), format!("{ns:.1}")]);

    // 2. class disabled (polling class in default mode)
    install_session(SessionConfig {
        sink: SinkKind::Null,
        mode: TracingMode::Default,
        ..Default::default()
    });
    let ns = per_event_ns(n, || {
        emit(polling, |e| {
            e.ptr(0xe0);
        });
    });
    table.row(&["disabled class".into(), format!("{ns:.1}")]);

    // 3. enabled, small payload (8 B)
    let ns_small = per_event_ns(n, || {
        emit(small, |e| {
            e.u64(7);
        });
    });
    table.row(&["enabled, 8B payload".into(), format!("{ns_small:.1}")]);

    // 4. enabled, full memcpy payload (44 B, 7 fields)
    let ns_full = per_event_ns(n, || {
        emit(memcpy, |e| {
            e.ptr(0x1150).ptr(0xff00_1000).ptr(0x7f00_2000).u64(1 << 20).ptr(0).u64(0).ptr(0);
        });
    });
    table.row(&["enabled, memcpy payload".into(), format!("{ns_full:.1}")]);

    let session = uninstall_session().unwrap();
    let stats = session.stats();

    println!("\n=== E11: tracepoint cost (paper: 'order of nanoseconds') ===\n");
    println!("{}", table.render());
    println!(
        "events written: {}  dropped: {} ({:.2}% drop rate at full speed)",
        stats.written,
        stats.dropped,
        stats.dropped as f64 * 100.0 / (stats.written + stats.dropped).max(1) as f64
    );
    println!(
        "sustained emit throughput: {:.1} M events/s (memcpy payload)",
        1e3 / ns_full
    );
}
