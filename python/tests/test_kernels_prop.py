"""Hypothesis property sweeps over kernel shapes vs the jnp oracles.

Arrays are generated from a drawn integer seed through numpy's PRNG — this
keeps hypothesis' example size tiny (it shrinks shapes and seeds, not float
lists) while still sweeping the shape/tile space.
"""

import numpy as np
import jax.numpy as jnp
import pytest

# The build container does not ship hypothesis (and installs are
# forbidden there): skip this module cleanly instead of erroring at
# collection. CI installs hypothesis, so the sweeps run on GitHub.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import conv1d, jacobi_step, lrn, matmul, ref, saxpy, softmax_xent

SETTINGS = dict(max_examples=25, deadline=None)
seed_st = st.integers(0, 2**32 - 1)


def _f32(seed, *shape, lo=-4.0, hi=4.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


@settings(**SETTINGS)
@given(seed_st, st.integers(1, 8), st.integers(1, 6))
def test_saxpy_prop(seed, blocks, logb):
    block = 1 << logb
    n = blocks * block
    a, x, y = _f32(seed, 1), _f32(seed + 1, n), _f32(seed + 2, n)
    got = saxpy(a, x, y, block=block)
    np.testing.assert_allclose(got, ref.ref_saxpy(a[0], x, y), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(seed_st, st.integers(1, 4), st.integers(3, 6), st.sampled_from([1, 3, 5, 9]))
def test_conv1d_prop(seed, btiles, logn, k):
    rows = 2
    b, n = btiles * rows, 1 << logn
    x = _f32(seed, b, n)
    w = _f32(seed + 1, k, lo=-1.0, hi=1.0)
    got = conv1d(x, w, rows=rows)
    np.testing.assert_allclose(got, ref.ref_conv1d(x, w), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(seed_st, st.integers(1, 3), st.integers(2, 5), st.sampled_from([1, 3, 5, 7]))
def test_lrn_prop(seed, b, logc, n):
    c, w = 1 << logc, 16
    x = _f32(seed, b, c, w)
    got = lrn(x, n=n)
    np.testing.assert_allclose(got, ref.ref_lrn(x, n=n), rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(seed_st, st.integers(1, 4), st.integers(2, 5))
def test_stencil_prop(seed, bands, logw):
    rows = 8
    h, w = bands * rows, 1 << logw
    g = _f32(seed, h, w)
    got = jacobi_step(g, rows=rows)
    np.testing.assert_allclose(got, ref.ref_stencil2d(g), rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(seed_st, st.integers(1, 3), st.integers(1, 3), st.integers(1, 3))
def test_matmul_prop(seed, mt, kt, nt):
    bm = bn = bk = 16
    m, k, n = mt * bm, kt * bk, nt * bn
    a = _f32(seed, m, k, lo=-2.0, hi=2.0)
    b = _f32(seed + 1, k, n, lo=-2.0, hi=2.0)
    got = matmul(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.ref_matmul(a, b), rtol=1e-3, atol=1e-3)


@settings(**SETTINGS)
@given(seed_st, st.integers(1, 4), st.integers(2, 6))
def test_xent_prop(seed, rtiles, logv):
    rows = 4
    b, v = rtiles * rows, 1 << logv
    logits = _f32(seed, b, v, lo=-8.0, hi=8.0)
    labels = jnp.asarray(
        np.random.default_rng(seed + 7).integers(0, v, size=b), jnp.int32
    )
    got = softmax_xent(logits, labels, rows=rows)
    np.testing.assert_allclose(
        got, ref.ref_softmax_xent(logits, labels), rtol=1e-3, atol=1e-3
    )
