"""AOT path tests: every model lowers to parseable HLO text + sane manifest."""

import os
import subprocess
import sys

import jax
import pytest

from compile.aot import to_hlo_text, _dtype_name, _shape_str
from compile.model import MODELS


@pytest.mark.parametrize("name", sorted(MODELS))
def test_model_lowers_to_hlo_text(name):
    fn, example_args = MODELS[name]
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "entry_computation_layout" in text.splitlines()[0]
    # No Mosaic custom-calls may leak through (kernels must be interpret=True).
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


@pytest.mark.parametrize("name", sorted(MODELS))
def test_model_output_shape_is_static(name):
    fn, example_args = MODELS[name]
    out = jax.eval_shape(fn, *example_args)
    assert all(isinstance(d, int) for d in out.shape)


def test_dtype_and_shape_helpers():
    import jax.numpy as jnp

    assert _dtype_name(jnp.float32) == "f32"
    assert _dtype_name(jnp.int32) == "i32"
    assert _shape_str((2, 3, 4)) == "2x3x4"
    assert _shape_str(()) == "scalar"


def test_aot_cli_writes_manifest(tmp_path):
    env = dict(os.environ)
    here = os.path.dirname(os.path.abspath(__file__))
    pkg_root = os.path.join(here, "..")
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
        cwd=pkg_root,
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr
    manifest = (tmp_path / "manifest.txt").read_text()
    for name in MODELS:
        assert f"kernel {name} {name}.hlo.txt" in manifest
        assert (tmp_path / f"{name}.hlo.txt").exists()
    # manifest grammar: every line is kernel/param/result
    for line in manifest.strip().splitlines():
        assert line.split()[0] in ("kernel", "param", "result")
