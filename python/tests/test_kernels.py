"""Kernel-vs-oracle correctness: every Pallas kernel against its jnp ref.

This is the CORE L1 correctness signal (fixed shapes matching the AOT
registry plus a few off-registry shapes); the hypothesis sweeps live in
test_kernels_prop.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import (
    conv1d,
    jacobi_step,
    lrn,
    matmul,
    ref,
    saxpy,
    softmax_xent,
)

RNG = np.random.default_rng(0)


def _f32(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


class TestSaxpy:
    def test_registry_shape(self):
        a, x, y = _f32(1), _f32(1 << 20), _f32(1 << 20)
        got = saxpy(a, x, y)
        np.testing.assert_allclose(got, ref.ref_saxpy(a[0], x, y), rtol=1e-5, atol=1e-6)

    def test_small_block(self):
        a, x, y = _f32(1), _f32(512), _f32(512)
        got = saxpy(a, x, y, block=128)
        np.testing.assert_allclose(got, ref.ref_saxpy(a[0], x, y), rtol=1e-5, atol=1e-6)

    def test_single_block(self):
        a, x, y = _f32(1), _f32(256), _f32(256)
        got = saxpy(a, x, y, block=256)
        np.testing.assert_allclose(got, ref.ref_saxpy(a[0], x, y), rtol=1e-5, atol=1e-6)

    def test_zero_scale(self):
        x, y = _f32(256), _f32(256)
        got = saxpy(jnp.zeros(1, jnp.float32), x, y, block=128)
        np.testing.assert_allclose(got, y, rtol=0)


class TestConv1d:
    @pytest.mark.parametrize("b,n,k,rows", [(64, 4096, 33, 8), (8, 64, 5, 4), (4, 128, 1, 2)])
    def test_vs_ref(self, b, n, k, rows):
        x, w = _f32(b, n), _f32(k)
        got = conv1d(x, w, rows=rows)
        np.testing.assert_allclose(got, ref.ref_conv1d(x, w), rtol=1e-4, atol=1e-5)

    def test_identity_tap(self):
        x = _f32(4, 64)
        w = jnp.zeros(5, jnp.float32).at[2].set(1.0)
        got = conv1d(x, w, rows=2)
        np.testing.assert_allclose(got, x, rtol=1e-6)

    def test_edge_padding_is_zero(self):
        # An averaging tap at the left edge only sees half the window.
        x = jnp.ones((2, 32), jnp.float32)
        w = jnp.ones(3, jnp.float32)
        got = conv1d(x, w, rows=2)
        assert got[0, 0] == pytest.approx(2.0)
        assert got[0, 1] == pytest.approx(3.0)
        assert got[0, -1] == pytest.approx(2.0)


class TestLrn:
    @pytest.mark.parametrize("b,c,w", [(32, 64, 256), (2, 16, 32), (1, 8, 128)])
    def test_vs_ref(self, b, c, w):
        x = _f32(b, c, w)
        got = lrn(x)
        np.testing.assert_allclose(got, ref.ref_lrn(x), rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("n", [1, 3, 7])
    def test_window_sizes(self, n):
        x = _f32(2, 16, 64)
        got = lrn(x, n=n)
        np.testing.assert_allclose(got, ref.ref_lrn(x, n=n), rtol=1e-5, atol=1e-6)

    def test_zero_input_is_zero(self):
        x = jnp.zeros((1, 8, 32), jnp.float32)
        np.testing.assert_array_equal(lrn(x), x)


class TestStencil:
    @pytest.mark.parametrize("h,w,rows", [(512, 512, 64), (128, 96, 32), (64, 64, 64)])
    def test_vs_ref(self, h, w, rows):
        g = _f32(h, w)
        got = jacobi_step(g, rows=rows)
        np.testing.assert_allclose(got, ref.ref_stencil2d(g), rtol=1e-5, atol=1e-6)

    def test_boundaries_fixed(self):
        g = _f32(64, 64)
        got = jacobi_step(g, rows=32)
        np.testing.assert_array_equal(got[0, :], g[0, :])
        np.testing.assert_array_equal(got[-1, :], g[-1, :])
        np.testing.assert_array_equal(got[:, 0], g[:, 0])
        np.testing.assert_array_equal(got[:, -1], g[:, -1])

    def test_constant_field_is_fixed_point(self):
        g = jnp.full((64, 64), 3.0, jnp.float32)
        np.testing.assert_allclose(jacobi_step(g, rows=32), g, rtol=1e-6)


class TestMatmul:
    @pytest.mark.parametrize(
        "m,k,n,tiles", [(256, 256, 256, (64, 64, 64)), (128, 64, 96, (32, 32, 32))]
    )
    def test_vs_ref(self, m, k, n, tiles):
        a, b = _f32(m, k), _f32(k, n)
        bm, bn, bk = tiles
        got = matmul(a, b, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, ref.ref_matmul(a, b), rtol=1e-4, atol=1e-4)

    def test_identity(self):
        a = _f32(64, 64)
        eye = jnp.eye(64, dtype=jnp.float32)
        got = matmul(a, eye, bm=32, bn=32, bk=32)
        np.testing.assert_allclose(got, a, rtol=1e-5, atol=1e-5)


class TestSoftmaxXent:
    @pytest.mark.parametrize("b,v,rows", [(256, 2048, 16), (32, 128, 8)])
    def test_vs_ref(self, b, v, rows):
        logits = _f32(b, v)
        labels = jnp.asarray(RNG.integers(0, v, size=b), jnp.int32)
        got = softmax_xent(logits, labels, rows=rows)
        np.testing.assert_allclose(
            got, ref.ref_softmax_xent(logits, labels), rtol=1e-4, atol=1e-5
        )

    def test_confident_correct_prediction_low_loss(self):
        logits = jnp.full((8, 16), -10.0, jnp.float32)
        logits = logits.at[jnp.arange(8), jnp.arange(8)].set(10.0)
        labels = jnp.arange(8, dtype=jnp.int32)
        got = softmax_xent(logits, labels, rows=8)
        assert float(jnp.max(got)) < 1e-3

    def test_shift_invariance(self):
        logits = _f32(16, 64)
        labels = jnp.asarray(RNG.integers(0, 64, size=16), jnp.int32)
        a = softmax_xent(logits, labels, rows=16)
        b = softmax_xent(logits + 100.0, labels, rows=16)
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
