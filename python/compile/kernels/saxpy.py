"""Pallas SAXPY kernel: y' = a*x + y.

Bandwidth-bound archetype used by the memcpy-heavy HeCBench-like apps.

TPU mapping (DESIGN.md §Hardware-Adaptation): the block is sized so that the
two input tiles plus the output tile fit in VMEM (3 * BLOCK * 4 B << 16 MiB);
the grid walks the vector in BLOCK-sized chunks so HBM<->VMEM traffic is a
single linear stream per operand — the role threadblock-striding plays in
the CUDA original.  interpret=True lowers this to plain HLO so the Rust
PJRT CPU client can execute it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 * 128 lanes * 64 sublanes: a comfortable f32 VMEM tile.
BLOCK = 65536


def _saxpy_kernel(a_ref, x_ref, y_ref, o_ref):
    # a is a (1,) scalar-prefetch-style operand kept in its own tiny block.
    a = a_ref[0]
    o_ref[...] = a * x_ref[...] + y_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def saxpy(a, x, y, block=BLOCK):
    """a: (1,) f32, x/y: (N,) f32 with N a multiple of ``block``."""
    (n,) = x.shape
    assert n % block == 0, f"N={n} must be a multiple of {block}"
    grid = (n // block,)
    return pl.pallas_call(
        _saxpy_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(a, x, y)
