"""Pallas across-channel Local Response Normalization.

The LRN mini-app is the workload of the paper's §4.3 HIPLZ case study; this
kernel is what the simulated GPU actually executes when the HIP frontend
launches it through the Level-Zero backend.

TPU mapping: grid over the batch dimension; each step holds one (C, W) image
in VMEM.  The size-n channel window is n shifted reads of the squared tile
(pad once into scratch-free padded load), accumulated in registers, then one
rsqrt-style power and a multiply — all VPU work, W on the 128-lane axis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lrn_kernel(x_ref, o_ref, *, n, k, alpha, beta, c):
    # x_ref: (1, C + n - 1, W) channel-padded image; o_ref: (1, C, W)
    x = x_ref[0]  # (C + n - 1, W), rows [half, half+C) are the real channels
    half = n // 2
    sq = x * x
    acc = jnp.zeros(o_ref.shape[1:], jnp.float32)
    for i in range(n):  # static unroll over the channel window
        acc = acc + sq[i : i + c, :]
    denom = (k + (alpha / n) * acc) ** beta
    o_ref[0] = x[half : half + c, :] / denom


@functools.partial(jax.jit, static_argnames=("n", "k", "alpha", "beta"))
def lrn(x, n=5, k=1.0, alpha=1e-4, beta=0.75):
    """x: (B, C, W) f32 -> (B, C, W) f32 across-channel LRN."""
    b, c, w = x.shape
    half = n // 2
    xp = jnp.pad(x, ((0, 0), (half, half), (0, 0)))
    kern = functools.partial(_lrn_kernel, n=n, k=k, alpha=alpha, beta=beta, c=c)
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, c + n - 1, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, c, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c, w), jnp.float32),
        interpret=True,
    )(xp)
