"""Pallas kernels (L1) and their pure-jnp oracles.

Every kernel is authored with ``interpret=True`` so it lowers to plain HLO
ops that the Rust PJRT CPU client can execute; see DESIGN.md
§Hardware-Adaptation for the TPU mapping notes in each module.
"""

from .saxpy import saxpy
from .conv1d import conv1d
from .lrn import lrn
from .stencil2d import jacobi_step
from .matmul import matmul
from .softmax import softmax_xent
from . import ref

__all__ = [
    "saxpy",
    "conv1d",
    "lrn",
    "jacobi_step",
    "matmul",
    "softmax_xent",
    "ref",
]
