"""Pallas tiled GEMM (f32).

Compute-bound archetype for the kernel-heavy HeCBench-like apps.

TPU mapping: classic MXU schedule — (BM, BK) x (BK, BN) tiles staged in VMEM,
grid (M/BM, N/BN, K/BK) with the K axis innermost so the f32 accumulator
tile stays resident in VMEM across the K loop (revolving accumulator), and
each MXU pass consumes one (BM,BK)x(BK,BN) pair.  On real TPU the tiles
would be bf16 into the systolic array with f32 accumulation; interpret=True
keeps everything f32 so the CPU PJRT numerics match the oracle exactly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, bm=64, bn=64, bk=64):
    """a: (M, K) f32, b: (K, N) f32 -> (M, N) f32; dims multiples of tiles."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
