"""Pallas batched 1-D convolution ('same', zero-padded).

This is the compute kernel behind the convolution1D HeCBench mini-app shown
in the paper's Fig. 5 timeline.

TPU mapping: each grid step loads a (ROWS, N) input tile plus the full tap
vector into VMEM and produces the matching output tile.  The K-tap reduction
is expressed as K shifted VMEM reads accumulated in registers — on TPU this
vectorizes across the 128-lane dimension (N) with the taps broadcast from
SMEM; there is no shared-memory halo exchange as in the CUDA version because
the whole row (plus pad) sits in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv1d_kernel(x_ref, w_ref, o_ref, *, k):
    # x_ref: (ROWS, N + K - 1) pre-padded rows; w_ref: (K,); o_ref: (ROWS, N)
    n = o_ref.shape[1]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for i in range(k):  # K is small + static: unrolled adds, no gather
        acc = acc + x_ref[:, i : i + n] * w_ref[i]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("rows",))
def conv1d(x, w, rows=8):
    """x: (B, N) f32, w: (K,) f32 with K odd; returns (B, N).

    B must be a multiple of ``rows`` (the batch tile height).
    """
    b, n = x.shape
    (k,) = w.shape
    assert k % 2 == 1, "K must be odd"
    assert b % rows == 0, f"B={b} must be a multiple of rows={rows}"
    half = k // 2
    xp = jnp.pad(x, ((0, 0), (half, half)))
    grid = (b // rows,)
    kern = functools.partial(_conv1d_kernel, k=k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, n + k - 1), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(xp, w)
