"""Pallas 5-point Jacobi stencil sweep.

Stand-in for the lattice-Boltzmann-style SPEChpc kernels (505.lbm, 519.clvleaf
archetypes): memory-bound structured-grid update.

TPU mapping: the grid walks row-bands.  The vertical halo is expressed by
feeding three *shifted views* of the padded grid (up / mid / down), each with
ordinary non-overlapping (ROWS, W) BlockSpecs — the Pallas equivalent of the
overlapping shared-memory tiles the CUDA original stages, without needing
overlapped block indexing.  Horizontal neighbours come from in-VMEM shifts.
Boundary cells pass through unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(up_ref, mid_ref, down_ref, o_ref, *, h, rows):
    up, mid, down = up_ref[...], mid_ref[...], down_ref[...]
    w = mid.shape[1]
    left = jnp.pad(mid[:, :-1], ((0, 0), (1, 0)))
    right = jnp.pad(mid[:, 1:], ((0, 0), (0, 1)))
    interior = 0.25 * (up + down + left + right)

    # First/last global rows and columns keep their old value.
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, w), 1)
    keep_col = (col == 0) | (col == w - 1)
    band_id = pl.program_id(0)
    row = jax.lax.broadcasted_iota(jnp.int32, (rows, w), 0) + band_id * rows
    keep_row = (row == 0) | (row == h - 1)
    o_ref[...] = jnp.where(keep_col | keep_row, mid, interior)


@functools.partial(jax.jit, static_argnames=("rows",))
def jacobi_step(g, rows=64):
    """One 5-point Jacobi sweep over g: (H, W) f32, H a multiple of rows."""
    h, w = g.shape
    assert h % rows == 0, f"H={h} must be a multiple of rows={rows}"
    gp = jnp.pad(g, ((1, 1), (0, 0)))  # one halo row above and below
    up, mid, down = gp[:h, :], gp[1 : h + 1, :], gp[2 : h + 2, :]
    kern = functools.partial(_jacobi_kernel, h=h, rows=rows)
    band = pl.BlockSpec((rows, w), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(h // rows,),
        in_specs=[band, band, band],
        out_specs=pl.BlockSpec((rows, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(up, mid, down)
