"""Pallas row-wise softmax cross-entropy kernel.

Archetype for the reduction-heavy apps (and a second VPU-bound kernel shape
for the hypothesis sweeps).

TPU mapping: grid over row-blocks; one (ROWS, V) tile of logits in VMEM per
step, labels in a tiny (ROWS,) int tile.  max / logsumexp are lane-axis
reductions; the label pick is a one-hot contraction (gathers are a poor fit
for the VPU, a masked sum is the idiomatic TPU form).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xent_kernel(logits_ref, labels_ref, o_ref):
    logits = logits_ref[...]  # (ROWS, V)
    labels = labels_ref[...]  # (ROWS,)
    rows, v = logits.shape
    m = jnp.max(logits, axis=-1, keepdims=True)
    s = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(s), axis=-1)) + m[:, 0]
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, v), 1)
    onehot = (col == labels[:, None]).astype(jnp.float32)
    picked = jnp.sum(logits * onehot, axis=-1)
    o_ref[...] = lse - picked


@functools.partial(jax.jit, static_argnames=("rows",))
def softmax_xent(logits, labels, rows=16):
    """logits: (B, V) f32, labels: (B,) i32 -> (B,) f32 per-row loss."""
    b, v = logits.shape
    assert b % rows == 0, f"B={b} must be a multiple of rows={rows}"
    return pl.pallas_call(
        _xent_kernel,
        grid=(b // rows,),
        in_specs=[
            pl.BlockSpec((rows, v), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(logits, labels)
