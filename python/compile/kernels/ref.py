"""Pure-jnp reference oracles for every Pallas kernel.

Each ``ref_*`` function is the semantic ground truth the Pallas kernels in
this package are tested against (pytest + hypothesis in ``python/tests``).
They are written in the most obvious jnp style — clarity over speed.
"""

import jax.numpy as jnp


def ref_saxpy(a, x, y):
    """y' = a * x + y, elementwise. a is a scalar (rank-0 or python float)."""
    return a * x + y


def ref_conv1d(x, w):
    """Batched 1-D 'same' convolution (cross-correlation).

    x: (B, N) input rows, w: (K,) taps with K odd.
    out[b, i] = sum_k x[b, i + k - K//2] * w[k], zero-padded at the edges.
    """
    b, n = x.shape
    (k,) = w.shape
    half = k // 2
    xp = jnp.pad(x, ((0, 0), (half, half)))
    # Gather K shifted views and contract against the taps.
    cols = jnp.stack([xp[:, i : i + n] for i in range(k)], axis=-1)  # (B,N,K)
    return jnp.einsum("bnk,k->bn", cols, w)


def ref_lrn(x, n=5, k=1.0, alpha=1e-4, beta=0.75):
    """Across-channel Local Response Normalization (AlexNet-style).

    x: (B, C, W). out[b,c,w] = x / (k + alpha/n * sum_{c' in win(c)} x^2)^beta
    where win(c) is the size-n channel window centered on c (clipped).
    """
    b, c, w = x.shape
    half = n // 2
    sq = x * x
    sqp = jnp.pad(sq, ((0, 0), (half, half), (0, 0)))
    acc = jnp.zeros_like(x)
    for i in range(n):
        acc = acc + sqp[:, i : i + c, :]
    denom = (k + (alpha / n) * acc) ** beta
    return x / denom


def ref_stencil2d(grid, steps=1):
    """steps x 5-point Jacobi sweeps on (H, W); boundary rows/cols held fixed."""

    def one(g):
        interior = 0.25 * (g[:-2, 1:-1] + g[2:, 1:-1] + g[1:-1, :-2] + g[1:-1, 2:])
        return g.at[1:-1, 1:-1].set(interior)

    out = grid
    for _ in range(steps):
        out = one(out)
    return out


def ref_matmul(a, b):
    """Plain f32 matmul, the oracle for the tiled Pallas GEMM."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def ref_softmax_xent(logits, labels):
    """Row-wise numerically-stable softmax cross-entropy.

    logits: (B, V); labels: (B,) int32. Returns (B,) per-row loss.
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    s = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(s), axis=-1)) + m[:, 0]
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - picked
