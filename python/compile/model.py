"""L2: JAX compute graphs around the Pallas kernels.

Each ``*_model`` is the computation one simulated-GPU kernel launch executes.
They wrap the L1 Pallas kernels with the surrounding (fusable) graph the
corresponding mini-app needs — bias/activation epilogues, multi-step sweeps —
so a launch is a single XLA executable with everything fused.

``MODELS`` is the AOT registry: name -> (fn, example_args).  ``aot.py``
lowers every entry to HLO text; the Rust runtime loads them by name.
"""

import jax
import jax.numpy as jnp

from .kernels import conv1d, jacobi_step, lrn, matmul, saxpy, softmax_xent

# ---------------------------------------------------------------------------
# Model functions (single output each; aot.py lowers with return_tuple=True).
# ---------------------------------------------------------------------------


def saxpy_model(a, x, y):
    """Bandwidth archetype: y' = a*x + y."""
    return saxpy(a, x, y)


def conv1d_model(x, w, bias):
    """convolution1D mini-app step: relu(conv(x, w) + bias)."""
    return jax.nn.relu(conv1d(x, w) + bias)


def lrn_model(x):
    """LRN mini-app step (the §4.3 HIPLZ workload)."""
    return lrn(x)


def stencil_model(g):
    """Four Jacobi sweeps per launch (the lbm-like SPEChpc archetype)."""
    for _ in range(4):
        g = jacobi_step(g)
    return g


def matmul_model(a, b, bias):
    """Compute archetype: gelu(a @ b + bias)."""
    return jax.nn.gelu(matmul(a, b) + bias[None, :])


def xent_model(logits, labels):
    """Reduction archetype: mean softmax cross-entropy (scalar-ish output)."""
    per_row = softmax_xent(logits, labels)
    return jnp.mean(per_row, keepdims=True)


# ---------------------------------------------------------------------------
# AOT registry: fixed launch shapes, mirrored by the Rust kernel catalog.
# ---------------------------------------------------------------------------

F32 = jnp.float32
I32 = jnp.int32


def _s(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


SAXPY_N = 1 << 20
CONV_B, CONV_N, CONV_K = 64, 4096, 33
LRN_B, LRN_C, LRN_W = 32, 64, 256
STENCIL_H, STENCIL_W = 512, 512
MM_M, MM_K, MM_N = 256, 256, 256
XENT_B, XENT_V = 256, 2048

MODELS = {
    "saxpy": (saxpy_model, (_s((1,)), _s((SAXPY_N,)), _s((SAXPY_N,)))),
    "conv1d": (conv1d_model, (_s((CONV_B, CONV_N)), _s((CONV_K,)), _s((CONV_B, CONV_N)))),
    "lrn": (lrn_model, (_s((LRN_B, LRN_C, LRN_W)),)),
    "stencil": (stencil_model, (_s((STENCIL_H, STENCIL_W)),)),
    "matmul": (matmul_model, (_s((MM_M, MM_K)), _s((MM_K, MM_N)), _s((MM_N,)))),
    "xent": (xent_model, (_s((XENT_B, XENT_V)), _s((XENT_B,), I32))),
}
