"""AOT compile path: lower every L2 model to HLO *text* artifacts.

HLO text — NOT ``lowered.compile().serialize()`` and NOT a serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (what the published
``xla`` 0.1.6 crate links) rejects; the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Outputs, under --out-dir (default ../artifacts relative to this file):
  <name>.hlo.txt      one per MODELS entry
  manifest.txt        line-based catalog the Rust runtime parses:
                        kernel <name> <file>
                        param <dtype> <d0>x<d1>x...   (repeated, in order)
                        result <dtype> <d0>x...
Run via ``make artifacts``; python never runs on the request path.
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import MODELS


def to_hlo_text(lowered) -> str:
    """StableHLO module -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    import numpy as np

    return {"float32": "f32", "int32": "i32", "float64": "f64", "int64": "i64"}[
        str(np.dtype(dt))
    ]


def _shape_str(shape) -> str:
    return "x".join(str(d) for d in shape) if shape else "scalar"


def main() -> int:
    here = os.path.dirname(os.path.abspath(__file__))
    default_out = os.path.join(here, "..", "..", "artifacts")
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=default_out)
    ap.add_argument("--only", default=None, help="comma-separated model names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest_lines = []
    for name, (fn, example_args) in sorted(MODELS.items()):
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_aval = jax.eval_shape(fn, *example_args)
        manifest_lines.append(f"kernel {name} {fname}")
        for i, a in enumerate(example_args):
            manifest_lines.append(
                f"param {_dtype_name(a.dtype)} {_shape_str(a.shape)}"
            )
        manifest_lines.append(
            f"result {_dtype_name(out_aval.dtype)} {_shape_str(out_aval.shape)}"
        )
        print(f"lowered {name:10s} -> {fname} ({len(text)} chars)")

    if only is None:
        with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest_lines) + "\n")
        print(f"wrote manifest with {len(MODELS)} kernels")
    return 0


if __name__ == "__main__":
    sys.exit(main())
