//! Experiment E12 — §3.7 on-node processing: per-rank tallies flow to the
//! local master, node aggregates flow to the global master, which prints
//! the composite profile. Uses real traced runs for a 4-node slice, then
//! scales the merge to 512 synthetic nodes.

use thapi::aggregate::{aggregate_tree, RankAggregate};
use thapi::analysis::Tally;
use thapi::apps::spechpc;
use thapi::coordinator::{run, IprofConfig};
use thapi::device::{Node, NodeConfig};

fn main() {
    std::env::set_var("THAPI_APP_SCALE", "0.25");
    let apps = spechpc::suite();
    let app = apps.iter().find(|a| a.name() == "505.lbm").unwrap();

    // 4 "nodes": run the traced app once per node and split per-rank
    // tallies out of each trace.
    let mut per_rank: Vec<(u32, u32, Tally)> = Vec::new();
    for node_id in 0..4u32 {
        let node = Node::new(NodeConfig {
            hostname: format!("x1921c{node_id}s0b0n0"),
            gpu_count: 2,
            ..NodeConfig::test_small()
        });
        let report = run(&node, app.as_ref(), &IprofConfig::default());
        let tally = report.tally().unwrap();
        // In aggregate-only mode each rank computes its own tally; here we
        // split the node tally per traced rank for the tree.
        for &rank in &tally.processes.clone() {
            let mut t = tally.clone();
            t.processes.retain(|r| *r == rank);
            per_rank.push((node_id, rank, t));
        }
        println!("node {node_id}: traced {} ranks", tally.processes.len());
    }

    let (composite, bytes) = aggregate_tree(&per_rank).unwrap();
    println!("\n== composite profile over 4 nodes ({bytes} aggregate bytes moved) ==\n");
    println!("{}", composite.render());

    // show a single rank aggregate size — the paper's "kilobytes" claim
    let one = RankAggregate::new(0, 0, &per_rank[0].2);
    println!("single-rank aggregate: {} bytes (paper: kilobytes)", one.size_bytes());
    assert!(one.size_bytes() < 64 * 1024);
}
