//! Experiment E2 — the Fig. 3 generation pipeline for `cuMemGetInfo`:
//! header prototype → API model → intermediary YAML → LTTng trace model
//! (event classes) → live registry ids.

use thapi::model::{metaparams, registry, yaml, Api};

fn main() {
    let reg = registry();
    let model = reg.model(Api::Cuda);
    let f = model.function("cuMemGetInfo").expect("cuMemGetInfo in header");

    println!("== 1. parsed from assets/headers/cuda.h ==\n");
    println!(
        "  {} {}({})",
        f.ret.name(),
        f.name,
        f.params
            .iter()
            .map(|p| format!("{} {}", p.ty.name(), p.name))
            .collect::<Vec<_>>()
            .join(", ")
    );

    println!("\n== 2. meta-parameters (Fig. 3 'Meta-parameter' block) ==\n");
    for m in metaparams::metaparams(Api::Cuda, "cuMemGetInfo") {
        println!("  - {m:?}  -> field {} at {}", m.field_name(), if m.at_entry() { "entry" } else { "exit" });
    }

    println!("\n== 3. intermediary YAML API model (functions: cuMemGetInfo) ==\n");
    let mut single = thapi::model::ApiModel {
        api: Some(Api::Cuda),
        functions: vec![f.clone()],
        enums: vec![],
    };
    single.api = Some(Api::Cuda);
    let y = yaml::emit_api_model(&single);
    println!("{y}");
    // round-trip proof
    let back = yaml::parse_api_model(&y).unwrap();
    assert_eq!(back.functions[0], *f, "YAML round-trip must be lossless");

    println!("== 4. generated LTTng trace model (event classes) ==\n");
    for name in ["lttng_ust_cuda:cuMemGetInfo_entry", "lttng_ust_cuda:cuMemGetInfo_exit"] {
        let c = reg.class(name).unwrap();
        println!("  TRACEPOINT_EVENT id={} {}", c.id, c.name);
        for fd in &c.fields {
            println!("      field {:<8} {:?}", fd.name, fd.ty);
        }
    }
    println!("\n(registry holds {} generated event classes)", thapi::model::class_count());
}
