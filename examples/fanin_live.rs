//! Multi-publisher fan-in (experiment E-fanin): two `iprof serve`-style
//! publishers on real localhost TCP sockets, one `iprof attach`-style
//! subscriber merging both into a single on-line tally.
//!
//! A workload is traced once, its stream set is split in half, and each
//! half is replayed through its own live hub and published as THRL
//! frames (docs/PROTOCOL.md) on its own socket — two "nodes" of a
//! fleet. The subscriber fan-in namespaces both publishers' stream ids
//! into one shared hub and drives the UNMODIFIED LiveSource merge +
//! tally over the union, asserting the result is byte-identical to
//! post-mortem analysis of the whole undivided trace and that the run
//! was lossless (the `--live-strict` bar).
//!
//! ```sh
//! cargo run --release --example fanin_live
//! ```

use std::net::{TcpListener, TcpStream};
use thapi::analysis::{AnalysisSink, TallySink};
use thapi::coordinator::{run, run_fanin, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::live::{replay_trace, LiveHub};
use thapi::remote::publish;
use thapi::tracer::btf::TraceData;

fn main() {
    std::env::set_var("THAPI_APP_SCALE", "0.3");
    let node = Node::new(NodeConfig::polaris());
    let apps = thapi::apps::spechpc::suite();
    let app = &apps[0];
    println!("== tracing {} once, then splitting it across 2 publishers ==", app.name());
    let r = run(&node, app.as_ref(), &IprofConfig::default());
    let trace = r.trace.as_ref().unwrap();
    assert!(trace.streams.len() > 1, "need a multi-stream trace to split");

    // post-mortem reference over the whole trace
    let pm_text = {
        let parsed = thapi::analysis::parse_trace(trace).unwrap();
        let mut sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let reports = thapi::analysis::run_pipeline(&parsed, &mut sinks);
        reports[0].payload().unwrap().to_string()
    };

    let mid = trace.streams.len() / 2;
    let halves = [
        TraceData { metadata: trace.metadata.clone(), streams: trace.streams[..mid].to_vec() },
        TraceData { metadata: trace.metadata.clone(), streams: trace.streams[mid..].to_vec() },
    ];
    let hubs = [
        LiveHub::new(&node.config.hostname, 4096, false),
        LiveHub::new(&node.config.hostname, 4096, false),
    ];
    let listeners = [
        TcpListener::bind("127.0.0.1:0").expect("bind"),
        TcpListener::bind("127.0.0.1:0").expect("bind"),
    ];
    let addrs = [
        listeners[0].local_addr().unwrap(),
        listeners[1].local_addr().unwrap(),
    ];
    println!("== publishers on {} and {} ==\n", addrs[0], addrs[1]);

    let report = std::thread::scope(|scope| {
        for ((listener, hub), half) in listeners.iter().zip(&hubs).zip(&halves) {
            scope.spawn(move || {
                let (conn, _) = listener.accept().expect("accept");
                publish(hub, conn).expect("publish")
            });
            scope.spawn(move || replay_trace(hub, half, 64));
        }
        let conns = vec![
            TcpStream::connect(addrs[0]).expect("connect"),
            TcpStream::connect(addrs[1]).expect("connect"),
        ];
        let sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        run_fanin(conns, 4096, sinks, None, |_| {}, &Default::default()).expect("fan-in attach")
    });

    println!("== union tally over both publishers ==\n");
    println!("{}", report.reports[0].payload().unwrap());
    for (i, stats) in report.stats.per.iter().enumerate() {
        println!(
            "publisher {i} ({}): streams {} | {} events merged | server received {} \
             dropped {} | {}",
            report.hostnames[i],
            report.origins[i].channels,
            report.origins[i].received,
            stats.server_received,
            stats.server_dropped,
            if stats.error.is_some() { "DIED" } else { "clean Eos" },
        );
    }
    println!(
        "union: {} merged | staleness mean {:.2}ms max {:.2}ms",
        report.latency.merged,
        report.latency.mean().as_secs_f64() * 1e3,
        report.latency.max.as_secs_f64() * 1e3,
    );

    // the --live-strict bar, asserted in-process
    assert_eq!(report.failed_publishers(), 0, "both publishers must end cleanly");
    assert_eq!(report.server_dropped(), 0, "loopback replay must be lossless");
    assert_eq!(report.latency.merged, trace.record_count());
    assert_eq!(
        report.reports[0].payload().unwrap(),
        pm_text,
        "fan-in union must be byte-identical to whole-trace post-mortem"
    );
    println!("\nfan-in union asserted byte-identical to whole-trace post-mortem; drops: 0");
}
