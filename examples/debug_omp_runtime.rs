//! Experiment E3 — the §4.1 case study: diagnosing the (closed-source)
//! OpenMP runtime's copy-engine misuse from the Level-Zero trace alone.
//!
//! Runs the same offload workload against the buggy runtime (all command
//! lists bound to the compute engine) and the fixed runtime (transfers on
//! the dedicated copy engine), and shows how the `command_completed`
//! profiling events expose the difference without any runtime source.

use std::sync::Arc;
use thapi::analysis;
use thapi::device::{AllocKind, EngineKind, Node, NodeConfig};
use thapi::intercept::omp::{OmpConfig, OmpRuntime};
use thapi::intercept::ze::ZeDriver;
use thapi::tracer::{btf, install_session, uninstall_session, SessionConfig};

fn run_and_count(node: &Arc<Node>, use_copy_engine: bool) -> (u64, u64) {
    install_session(SessionConfig::default());
    let omp = OmpRuntime::new(ZeDriver::new(node.clone()), OmpConfig { use_copy_engine });
    let bytes = 4u64 << 20;
    let (_, d) = omp.omp_target_alloc(bytes, 0);
    let host = node.gpu(0).pool.alloc(AllocKind::Host, bytes).unwrap();
    for _ in 0..8 {
        omp.omp_target_memcpy(d, host, bytes, 0, 0, 0, -1);
        omp.omp_target_memcpy(host, d, bytes, 0, 0, -1, 0);
    }
    omp.omp_target_free(d, 0);
    let _ = node.gpu(0).pool.free(host);
    let session = uninstall_session().unwrap();
    let trace = btf::collect(&session, &[]);
    let parsed = analysis::parse_trace(&trace).unwrap();

    // Lazy streaming pass: profiling events are counted as they merge,
    // without materializing the muxed sequence.
    let (mut on_compute, mut on_copy) = (0u64, 0u64);
    for m in analysis::MessageSource::new(&parsed) {
        if m.class.name == "lttng_ust_profiling:command_completed"
            && m.field("kind").unwrap().as_str() == "memcpy"
        {
            if m.field("engine_kind").unwrap().as_u64() == EngineKind::Copy.code() as u64 {
                on_copy += 1;
            } else {
                on_compute += 1;
            }
        }
    }
    (on_compute, on_copy)
}

fn main() {
    let node = Node::new(NodeConfig::test_small());

    println!("== §4.1: tracing the 'closed-source' OpenMP runtime ==\n");
    let (compute, copy) = run_and_count(&node, false);
    println!(
        "buggy runtime:  {compute} transfers on ComputeEngine, {copy} on CopyEngine"
    );
    println!(
        "  -> trace shows the runtime does NOT leverage the dedicated copy engine;\n\
         \x20  all command lists are bound to the compute engine (the bug we report)\n"
    );
    assert_eq!(copy, 0);

    let (compute2, copy2) = run_and_count(&node, true);
    println!(
        "fixed runtime:  {compute2} transfers on ComputeEngine, {copy2} on CopyEngine"
    );
    println!("  -> after the fix, data transfers use the dedicated copy engine\n");
    assert_eq!(compute2, 0);

    println!(
        "case study reproduced: API-call traces alone were sufficient context to\n\
         analyze a proprietary runtime and report the performance issue."
    );
}
