//! Remote live viewer (experiment E-remote): `iprof serve` + `iprof
//! attach` in one process, over a real localhost TCP socket.
//!
//! The publisher thread traces a workload and relays its live per-stream
//! channels as THRL frames (docs/PROTOCOL.md); the subscriber thread
//! attaches, mirrors the hub, and drives the UNMODIFIED LiveSource merge
//! + tally sink — interim tables print while the traced app is still
//! running on the other end of the socket.
//!
//! ```sh
//! cargo run --release --example remote_live
//! ```

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;
use thapi::analysis::{AnalysisSink, TallySink};
use thapi::coordinator::{run_attach, run_serve, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::live::LiveConfig;

fn main() {
    std::env::set_var("THAPI_APP_SCALE", "0.6");
    let node = Node::new(NodeConfig::test_small());
    let apps = thapi::apps::hecbench::suite();
    let app = apps.iter().find(|a| a.name() == "jacobi2D-ze").unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    println!("== publisher on {addr}, tracing {} ==\n", app.name());

    std::thread::scope(|scope| {
        // Publisher: accept one subscriber, then run the traced workload.
        let serve = scope.spawn(|| {
            let (conn, _) = listener.accept().expect("accept");
            let live_cfg = LiveConfig { channel_depth: 4096, retain: false, refresh: None };
            run_serve(
                &node,
                app.as_ref(),
                &IprofConfig::default(),
                &live_cfg,
                conn,
                thapi::remote::VERSION,
                &Default::default(),
            )
            .expect("publish")
        });

        // Subscriber: attach over TCP and tally on-line.
        let conn = TcpStream::connect(addr).expect("connect");
        let sinks: Vec<Box<dyn AnalysisSink>> = vec![Box::new(TallySink::new())];
        let refreshes = AtomicUsize::new(0);
        let attach = run_attach(conn, 4096, sinks, Some(Duration::from_millis(100)), |text| {
            let n = refreshes.fetch_add(1, Ordering::Relaxed) + 1;
            println!("-- interim remote tally #{n} (app still running remotely) --");
            for line in text.lines().take(5) {
                println!("{line}");
            }
            println!();
        })
        .expect("attach");
        let serve = serve.join().expect("serve thread");

        println!("== final remote tally (same bytes a local --live run prints) ==\n");
        println!("{}", attach.reports[0].payload().unwrap());
        println!(
            "publisher: wall {:.3}s | {} events written | {} relayed in {} frames ({}B) | \
             {} dropped",
            serve.wall.as_secs_f64(),
            serve.stats.written,
            serve.publish.events,
            serve.publish.frames,
            serve.publish.bytes,
            serve.total_dropped(),
        );
        println!(
            "subscriber: host {} | {} merged | server received {} dropped {} | \
             staleness mean {:.2}ms max {:.2}ms | interim reports: {}",
            attach.hostname,
            attach.latency.merged,
            attach.remote.server_received,
            attach.remote.server_dropped,
            attach.latency.mean().as_secs_f64() * 1e3,
            attach.latency.max.as_secs_f64() * 1e3,
            refreshes.load(Ordering::Relaxed),
        );
        assert_eq!(
            serve.total_dropped(),
            0,
            "loopback at this scale must be lossless"
        );
    });
}
