//! End-to-end driver (DESIGN.md §End-to-end validation).
//!
//! Proves all layers compose on a real workload: runs the full
//! HeCBench-like suite (real Pallas→HLO→PJRT kernels) under `iprof`
//! across the six §5.2 configurations plus baseline, and reports the
//! paper's headline metric — tracing overhead per configuration — along
//! with trace sizes, a tally, a timeline and a validation report for one
//! representative app. Results are recorded in EXPERIMENTS.md.

use thapi::analysis;
use thapi::apps::hecbench;
use thapi::bench_support::{mean_of, median_of, Table};
use thapi::coordinator::{overhead_pct, run, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::tracer::{SinkKind, TracingMode};

fn main() {
    if std::env::var("THAPI_APP_SCALE").is_err() {
        std::env::set_var("THAPI_APP_SCALE", "0.3");
    }
    let node = Node::new(NodeConfig::test_small());
    let apps = hecbench::suite();

    let configs: Vec<IprofConfig> = [
        (TracingMode::Minimal, false),
        (TracingMode::Default, false),
        (TracingMode::Full, false),
        (TracingMode::Minimal, true),
        (TracingMode::Default, true),
        (TracingMode::Full, true),
    ]
    .iter()
    .map(|(m, s)| {
        let mut c = IprofConfig::paper_config(*m, *s);
        c.sink = SinkKind::Null;
        c
    })
    .collect();
    let labels: Vec<String> = configs.iter().map(|c| c.label()).collect();

    let mut overheads: Vec<Vec<f64>> = vec![Vec::new(); configs.len()];
    let mut events: Vec<u64> = vec![0; configs.len()];
    for app in &apps {
        let _ = run(&node, app.as_ref(), &IprofConfig::baseline()); // warmup
        let base = (0..2)
            .map(|_| run(&node, app.as_ref(), &IprofConfig::baseline()).wall)
            .min()
            .unwrap();
        for (ci, c) in configs.iter().enumerate() {
            let r = run(&node, app.as_ref(), c);
            overheads[ci].push(overhead_pct(base, r.wall));
            events[ci] += r.stats.as_ref().map(|s| s.written).unwrap_or(0);
        }
        eprintln!("e2e: {} done", app.name());
    }

    println!("\n=== E2E: headline metric — tracing overhead across the suite ===\n");
    let mut t = Table::new(&["config", "mean %", "median %", "events"]);
    for (ci, label) in labels.iter().enumerate() {
        t.row(&[
            label.clone(),
            format!("{:.2}", mean_of(&overheads[ci])),
            format!("{:.2}", median_of(&overheads[ci])),
            events[ci].to_string(),
        ]);
    }
    println!("{}", t.render());

    // One representative app end-to-end through every analysis plugin.
    let app = apps.iter().find(|a| a.name() == "lrn-hip").unwrap();
    let report = run(&node, app.as_ref(), &IprofConfig::default());
    let trace = report.trace.as_ref().unwrap();
    let msgs = analysis::mux(&analysis::parse_trace(trace).unwrap());
    let intervals = analysis::pair_intervals(&msgs);
    let tally = analysis::Tally::build(&intervals, &msgs);
    println!("=== tally (lrn-hip) ===\n{}", tally.render());
    let json = analysis::timeline_json(&intervals, &msgs);
    std::fs::write("e2e_lrn_hip.trace.json", &json).unwrap();
    println!("timeline: wrote e2e_lrn_hip.trace.json ({} bytes)", json.len());
    let findings = analysis::validate(&msgs);
    println!("validation: {} finding(s)", findings.len());
    println!("\nE2E complete: AOT kernels -> PJRT runtime -> traced frontends -> BTF -> plugins.");
}
