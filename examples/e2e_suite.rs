//! End-to-end driver (DESIGN.md §End-to-end validation).
//!
//! Proves all layers compose on a real workload: runs the full
//! HeCBench-like suite (real Pallas→HLO→PJRT kernels) under `iprof`
//! across the six §5.2 configurations plus baseline, and reports the
//! paper's headline metric — tracing overhead per configuration — along
//! with trace sizes, a tally, a timeline and a validation report for one
//! representative app. Results are recorded in EXPERIMENTS.md.

use thapi::analysis;
use thapi::apps::hecbench;
use thapi::bench_support::{mean_of, median_of, Table};
use thapi::coordinator::{overhead_pct, run, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::tracer::{SinkKind, TracingMode};

fn main() {
    if std::env::var("THAPI_APP_SCALE").is_err() {
        std::env::set_var("THAPI_APP_SCALE", "0.3");
    }
    let node = Node::new(NodeConfig::test_small());
    let apps = hecbench::suite();

    let configs: Vec<IprofConfig> = [
        (TracingMode::Minimal, false),
        (TracingMode::Default, false),
        (TracingMode::Full, false),
        (TracingMode::Minimal, true),
        (TracingMode::Default, true),
        (TracingMode::Full, true),
    ]
    .iter()
    .map(|(m, s)| {
        let mut c = IprofConfig::paper_config(*m, *s);
        c.sink = SinkKind::Null;
        c
    })
    .collect();
    let labels: Vec<String> = configs.iter().map(|c| c.label()).collect();

    let mut overheads: Vec<Vec<f64>> = vec![Vec::new(); configs.len()];
    let mut events: Vec<u64> = vec![0; configs.len()];
    for app in &apps {
        let _ = run(&node, app.as_ref(), &IprofConfig::baseline()); // warmup
        let base = (0..2)
            .map(|_| run(&node, app.as_ref(), &IprofConfig::baseline()).wall)
            .min()
            .unwrap();
        for (ci, c) in configs.iter().enumerate() {
            let r = run(&node, app.as_ref(), c);
            overheads[ci].push(overhead_pct(base, r.wall));
            events[ci] += r.stats.as_ref().map(|s| s.written).unwrap_or(0);
        }
        eprintln!("e2e: {} done", app.name());
    }

    println!("\n=== E2E: headline metric — tracing overhead across the suite ===\n");
    let mut t = Table::new(&["config", "mean %", "median %", "events"]);
    for (ci, label) in labels.iter().enumerate() {
        t.row(&[
            label.clone(),
            format!("{:.2}", mean_of(&overheads[ci])),
            format!("{:.2}", median_of(&overheads[ci])),
            events[ci].to_string(),
        ]);
    }
    println!("{}", t.render());

    // One representative app end-to-end through every analysis plugin —
    // a single streaming pass fans out to all three sinks at once.
    let app = apps.iter().find(|a| a.name() == "lrn-hip").unwrap();
    let report = run(&node, app.as_ref(), &IprofConfig::default());
    let mut sinks: Vec<Box<dyn analysis::AnalysisSink>> = vec![
        Box::new(analysis::TallySink::new()),
        Box::new(analysis::TimelineSink::new()),
        Box::new(analysis::ValidateSink::new()),
    ];
    let reports = report.analyze(&mut sinks).unwrap().unwrap();
    println!("=== tally (lrn-hip) ===\n{}", reports[0].payload().unwrap());
    let json = reports[1].payload().unwrap();
    std::fs::write("e2e_lrn_hip.trace.json", json).unwrap();
    println!("timeline: wrote e2e_lrn_hip.trace.json ({} bytes)", json.len());
    println!(
        "validation report:\n{}",
        reports[2].payload().unwrap().lines().next().unwrap_or("")
    );
    println!("\nE2E complete: AOT kernels -> PJRT runtime -> traced frontends -> BTF -> plugins (one pass, three sinks).");
}
