//! Experiment E5 — the §4.3 case study: analyzing the HIPLZ layering for
//! the LRN mini-app, tally + layering breakdown.

use thapi::analysis;
use thapi::apps::hecbench;
use thapi::coordinator::{run, IprofConfig};
use thapi::device::{Node, NodeConfig};

fn main() {
    std::env::set_var("THAPI_APP_SCALE", "0.6");
    let node = Node::new(NodeConfig::aurora());
    let apps = hecbench::suite();
    let lrn = apps.iter().find(|a| a.name() == "lrn-hip").unwrap();

    println!("== §4.3: LRN (HIP) on Aurora via HIPLZ (HIP -> Level-Zero) ==\n");
    let report = run(&node, lrn.as_ref(), &IprofConfig::default());
    let tally = report.tally().unwrap();
    println!("{}", tally.render());

    // Layering analysis: how hipDeviceSynchronize decomposes into the
    // zeEventHostSynchronize spin lock. Spans come straight from the
    // streaming graph (lazy mux -> incremental pairing), no Vec<EventMsg>.
    let trace = report.trace.as_ref().unwrap();
    let parsed = analysis::parse_trace(trace).unwrap();
    let intervals = analysis::intervals_of(&parsed);

    let hip_sync: Vec<_> = intervals.iter().filter(|i| i.name == "hipDeviceSynchronize").collect();
    let ze_spin: Vec<_> =
        intervals.iter().filter(|i| i.name == "zeEventHostSynchronize").collect();
    let nested: usize = ze_spin
        .iter()
        .filter(|z| hip_sync.iter().any(|h| h.start <= z.start && z.end <= h.end))
        .count();
    println!(
        "layering: {} hipDeviceSynchronize calls decompose into {} zeEventHostSynchronize \
         calls ({} nested inside a hip sync span)",
        hip_sync.len(),
        ze_spin.len(),
        nested
    );
    assert!(ze_spin.len() > hip_sync.len(), "spin-lock layering must be visible");

    // Depth histogram: depth 0 = HIP API, depth 1 = the ZE calls it spawns.
    let mut by_depth = std::collections::BTreeMap::new();
    for iv in &intervals {
        *by_depth.entry(iv.depth).or_insert(0u64) += 1;
    }
    println!("interval depth histogram (0 = app-facing API, 1 = backend): {by_depth:?}");
}
