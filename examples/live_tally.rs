//! Live tally (experiment E-live): analyze a workload ON-LINE.
//!
//! `iprof --live -a tally --refresh 100` in library form: the session's
//! consumer thread decodes ring records as it drains them and feeds the
//! tally sink through bounded, beacon-watermarked channels — interim
//! tables print while the workload is still executing, and no trace is
//! ever materialized (analysis memory is O(streams × channel depth)).
//!
//! ```sh
//! cargo run --release --example live_tally
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;
use thapi::analysis::{AnalysisSink, TallySink};
use thapi::coordinator::{run_live, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::live::LiveConfig;

fn main() {
    std::env::set_var("THAPI_APP_SCALE", "0.6");
    let node = Node::new(NodeConfig::test_small());
    let apps = thapi::apps::hecbench::suite();
    let app = apps.iter().find(|a| a.name() == "jacobi2D-ze").unwrap();

    println!("== live-tracing {} (tally runs while the app executes) ==\n", app.name());
    let live_cfg = LiveConfig {
        channel_depth: 1024,
        retain: false,
        refresh: Some(Duration::from_millis(100)),
    };
    let sinks: Vec<Box<dyn AnalysisSink + Send>> = vec![Box::new(TallySink::new())];
    let refreshes = AtomicUsize::new(0);
    let report = run_live(&node, app.as_ref(), &IprofConfig::default(), &live_cfg, sinks, |text| {
        let n = refreshes.fetch_add(1, Ordering::Relaxed) + 1;
        println!("-- interim tally #{n} (application still running) --");
        // print the header + top three rows, like a `top` for APIs
        for line in text.lines().take(5) {
            println!("{line}");
        }
        println!();
    });

    println!("== final tally (same bytes a post-mortem run would print) ==\n");
    println!("{}", report.reports[0].payload().unwrap());
    println!(
        "wall {:.3}s | {} events written, {} merged on-line, {} dropped | \
         {} beacons | staleness mean {:.2}ms max {:.2}ms | interim reports: {}",
        report.wall.as_secs_f64(),
        report.stats.written,
        report.latency.merged,
        report.total_dropped(),
        report.live.beacons,
        report.latency.mean().as_secs_f64() * 1e3,
        report.latency.max.as_secs_f64() * 1e3,
        refreshes.load(Ordering::Relaxed),
    );
    println!(
        "analysis-side memory: {} channels x {} messages (bounded) — no TraceData, no ParsedTrace",
        report.live.channels, live_cfg.channel_depth
    );
}
