//! Quickstart (experiment E1): trace one mini-app, show the §1.1
//! full-context event detail, print the tally.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use thapi::analysis;
use thapi::apps::hecbench;
use thapi::coordinator::{run, IprofConfig};
use thapi::device::{Node, NodeConfig};

fn main() {
    std::env::set_var("THAPI_APP_SCALE", "0.3");
    let node = Node::new(NodeConfig::test_small());
    let apps = hecbench::suite();
    let app = apps.iter().find(|a| a.name() == "convolution1D-ze").unwrap();

    println!("== tracing {} with iprof (default mode) ==\n", app.name());
    let report = run(&node, app.as_ref(), &IprofConfig::default());
    let stats = report.stats.as_ref().unwrap();
    println!(
        "wall: {:.3}s   events: {}   dropped: {}   trace: {} bytes\n",
        report.wall.as_secs_f64(),
        stats.written,
        stats.dropped,
        report.trace_bytes()
    );

    let trace = report.trace.as_ref().unwrap();
    let parsed = analysis::parse_trace(trace).unwrap();

    // The paper's §1.1 example: what THAPI records for one
    // zeCommandListAppendMemoryCopy_entry — every argument, with the
    // host/device address spaces readable off the pointers. The lazy
    // MessageSource stops merging as soon as the event is found.
    println!("== §1.1 event detail (vs TAU's name+timestamp only) ==\n");
    let memcpy = analysis::MessageSource::new(&parsed)
        .find(|m| m.class.name == "lttng_ust_ze:zeCommandListAppendMemoryCopy_entry")
        .expect("memcpy event in trace");
    println!("{}\n", analysis::pretty::format_event(memcpy));
    let dst = memcpy.field("dstptr").unwrap().as_u64();
    let src = memcpy.field("srcptr").unwrap().as_u64();
    println!(
        "-> dst {:#x} starts 0x{:02x}.. ({}), src {:#x} starts 0x{:02x}.. ({}): host-to-device transfer of {} bytes\n",
        dst,
        dst >> 56,
        if dst >> 56 == 0xff { "device" } else { "host" },
        src,
        src >> 56,
        if src >> 56 == 0xff { "device" } else { "host" },
        memcpy.field("size").unwrap().as_u64()
    );

    println!("== tally ==\n");
    println!("{}", report.tally().unwrap().render());
}
