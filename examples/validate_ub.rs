//! Experiment E4 — the §4.2 case study: post-mortem validation of
//! low-level API mistakes.
//!
//! Runs a deliberately sloppy Level-Zero application (uninitialized
//! `pNext`, an event that is never destroyed, a command list re-executed
//! without reset, a zero-byte copy) and a clean one, and prints the
//! validation plugin's reports for both.

use std::sync::Arc;
use thapi::analysis::{self, validate::render_report, Severity};
use thapi::device::{Node, NodeConfig};
use thapi::intercept::ze::{ZeDeviceProperties, ZeDriver};
use thapi::tracer::{btf, install_session, uninstall_session, SessionConfig};

fn trace_app(node: &Arc<Node>, sloppy: bool) -> Vec<analysis::Finding> {
    install_session(SessionConfig::default());
    let ze = ZeDriver::new(node.clone());
    ze.ze_init(0);
    let mut drivers = vec![];
    ze.ze_driver_get(&mut drivers);
    let mut devices = vec![];
    ze.ze_device_get(drivers[0], &mut devices);
    let dev = devices[0];
    let (_, ctx) = ze.ze_context_create(drivers[0]);

    // --- the §4.2 pNext mistake -------------------------------------
    let mut props = ZeDeviceProperties {
        // C: `ze_device_properties_t device_properties;` — stack garbage.
        p_next: if sloppy { 0xdead_beef_0bad_f00d } else { 0 },
        ..Default::default()
    };
    ze.ze_device_get_properties(dev, &mut props);

    // --- events ------------------------------------------------------
    let (_, pool) = ze.ze_event_pool_create(ctx, 4);
    let (_, ev) = ze.ze_event_create(pool);
    let (_, ev2) = ze.ze_event_create(pool);
    ze.ze_event_destroy(ev2);
    if !sloppy {
        ze.ze_event_destroy(ev); // clean app releases everything
    }

    // --- command list reuse ------------------------------------------
    let (_, queue) = ze.ze_command_queue_create(ctx, dev, 0);
    let (_, list) = ze.ze_command_list_create(ctx, dev);
    let (_, h) = ze.ze_mem_alloc_host(ctx, 4096, 64);
    let (_, d) = ze.ze_mem_alloc_device(ctx, 4096, 64, dev);
    ze.ze_command_list_append_memory_copy(list, d, h, 4096, 0);
    if sloppy {
        ze.ze_command_list_append_memory_copy(list, d, h, 0, 0); // zero bytes
    }
    ze.ze_command_list_close(list);
    ze.ze_command_queue_execute_command_lists(queue, &[list]);
    ze.ze_command_queue_synchronize(queue, u64::MAX);
    if sloppy {
        // UB in real Level-Zero: close + execute again without reset
        ze.ze_command_list_close(list);
        ze.ze_command_queue_execute_command_lists(queue, &[list]);
        ze.ze_command_queue_synchronize(queue, u64::MAX);
    } else {
        ze.ze_command_list_reset(list);
    }

    ze.ze_mem_free(ctx, h);
    ze.ze_mem_free(ctx, d);
    ze.ze_command_list_destroy(list);
    ze.ze_command_queue_destroy(queue);
    ze.ze_event_pool_destroy(pool);
    ze.ze_context_destroy(ctx);

    let session = uninstall_session().unwrap();
    let trace = btf::collect(&session, &[]);
    let parsed = analysis::parse_trace(&trace).unwrap();

    // Streaming validation: rules observe each message as it merges.
    let mut v = analysis::Validator::new();
    for m in analysis::MessageSource::new(&parsed) {
        v.observe(m);
    }
    v.finish()
}

fn main() {
    let node = Node::new(NodeConfig::test_small());

    println!("== §4.2: post-mortem validation — sloppy application ==\n");
    let findings = trace_app(&node, true);
    print!("{}", render_report(&findings));
    assert!(findings.iter().any(|f| f.rule == "ze-uninitialized-pnext"));
    assert!(findings.iter().any(|f| f.rule == "unreleased-event"));
    assert!(findings.iter().any(|f| f.rule == "ze-list-not-reset"));
    assert!(findings.iter().any(|f| f.severity == Severity::Error));

    println!("\n== same application, fixed ==\n");
    let findings = trace_app(&node, false);
    print!("{}", render_report(&findings));
    assert!(
        !findings.iter().any(|f| f.severity == Severity::Error),
        "clean app must have no errors"
    );
    println!("\ncase study reproduced: the validation plugin catches the pNext UB,\nunreleased events and non-reset command lists post-mortem.");
}
