//! Experiment E6 — Fig. 5: timeline of traces + device telemetry for the
//! convolution1D benchmark, exported as Perfetto-compatible JSON.

use thapi::analysis;
use thapi::apps::hecbench;
use thapi::coordinator::{run, IprofConfig};
use thapi::device::{Node, NodeConfig};
use thapi::sampling::SamplingConfig;
use thapi::tracer::TracingMode;

fn main() {
    std::env::set_var("THAPI_APP_SCALE", "0.6");
    let node = Node::new(NodeConfig::aurora());
    let apps = hecbench::suite();
    let app = apps.iter().find(|a| a.name() == "convolution1D-ze").unwrap();

    // TS-default with a fast sampling interval so short runs still get
    // plenty of telemetry rows (paper default is 50 ms).
    let mut config = IprofConfig::paper_config(TracingMode::Default, true);
    config.sampling = Some(SamplingConfig { interval: std::time::Duration::from_millis(5) });

    println!("== Fig. 5: convolution1D with device sampling ==\n");
    let report = run(&node, app.as_ref(), &config);
    let trace = report.trace.as_ref().unwrap();
    let parsed = analysis::parse_trace(trace).unwrap();

    // One streaming pass renders the Perfetto JSON.
    let mut sinks: Vec<Box<dyn analysis::AnalysisSink>> =
        vec![Box::new(analysis::TimelineSink::new())];
    let reports = analysis::run_pipeline(&parsed, &mut sinks);
    let json = reports[0].payload().unwrap();

    let out = "convolution1D.trace.json";
    std::fs::write(out, json).unwrap();

    // Row inventory, mirroring the paper's Fig. 5 description — a second
    // lazy pass over the borrowed streams (no materialized event vector).
    let mut host_spans = 0usize;
    let mut device_spans = 0usize;
    let mut telemetry = 0usize;
    let mut rows = std::collections::BTreeSet::new();
    for m in analysis::MessageSource::new(&parsed) {
        // every entry becomes exactly one span (paired or dangling)
        if m.class.is_entry() {
            host_spans += 1;
        }
        if m.class.name.contains("command_completed") {
            device_spans += 1;
        }
        if m.class.name.contains("sampling") {
            telemetry += 1;
        }
        match m.class.name.as_str() {
            "lttng_ust_sampling:gpu_power" => {
                rows.insert(format!("GPU Power Domain {}", m.field("domain").unwrap().as_u64()));
            }
            "lttng_ust_sampling:gpu_frequency" => {
                rows.insert(format!(
                    "GPU Frequency Domain {}",
                    m.field("domain").unwrap().as_u64()
                ));
            }
            "lttng_ust_sampling:gpu_engine_util" => {
                let kind = if m.field("engine_kind").unwrap().as_u64() == 0 {
                    "ComputeEngine"
                } else {
                    "CopyEngine"
                };
                rows.insert(format!(
                    "{kind} (%) Domain {}",
                    m.field("domain").unwrap().as_u64()
                ));
            }
            _ => {}
        }
    }
    println!("timeline rows (per GPU):");
    for r in &rows {
        println!("  {r}");
    }
    println!(
        "\nhost spans: {host_spans}   device spans: {device_spans}   telemetry points: {telemetry}"
    );
    println!("\nwrote {out} ({} bytes) — open at https://ui.perfetto.dev", json.len());
    assert!(rows.iter().any(|r| r.contains("Power Domain 0")));
    assert!(rows.iter().any(|r| r.contains("ComputeEngine (%) Domain 0")));
}
