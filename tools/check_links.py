#!/usr/bin/env python3
"""Markdown link checker for the repo's docs surface.

Checks every relative link target in the given markdown files (defaults
to docs/*.md, ROADMAP.md, rust/ARCHITECTURE.md) against the filesystem:
a `[text](path)` or `[text](path#anchor)` whose `path` does not exist —
file or directory, resolved against the linking file's own directory —
fails the run. External links (http/https/mailto) are skipped: CI must
not flake on someone else's uptime. Anchors are checked only for
markdown targets we also scanned, by slugifying their headings the way
GitHub does.

Usage: python3 tools/check_links.py [file.md ...]
Exit status: 0 = all links resolve, 1 = at least one broken link.
"""

import glob
import os
import re
import sys

LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces to dashes."""
    heading = re.sub(r"[`*_]", "", heading.strip())
    slug = []
    for ch in heading.lower():
        if ch.isalnum():
            slug.append(ch)
        elif ch in (" ", "-"):
            slug.append("-")
        # other punctuation drops out
    return "".join(slug)


def headings_of(path: str) -> set:
    try:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    except OSError:
        return set()
    return {github_slug(h) for h in HEADING_RE.findall(text)}


def check(files):
    errors = []
    anchor_cache = {}
    for md in files:
        base = os.path.dirname(os.path.abspath(md))
        with open(md, encoding="utf-8") as f:
            text = f.read()
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
                # external, or an in-file anchor: check the latter
                if target.startswith("#"):
                    own = anchor_cache.setdefault(md, headings_of(md))
                    if github_slug(target[1:]) not in own and target[1:] not in own:
                        errors.append(f"{md}: broken in-file anchor {target}")
                continue
            path, _, anchor = target.partition("#")
            resolved = os.path.normpath(os.path.join(base, path))
            if not os.path.exists(resolved):
                errors.append(f"{md}: broken link {target} -> {resolved}")
                continue
            if anchor and resolved.endswith(".md"):
                anchors = anchor_cache.setdefault(resolved, headings_of(resolved))
                if github_slug(anchor) not in anchors and anchor not in anchors:
                    errors.append(f"{md}: broken anchor {target}")
    return errors


def main(argv):
    files = argv[1:]
    if not files:
        files = sorted(glob.glob("docs/*.md")) + ["ROADMAP.md", "rust/ARCHITECTURE.md"]
    missing = [f for f in files if not os.path.exists(f)]
    if missing:
        print("no such file(s): " + ", ".join(missing))
        return 1
    errors = check(files)
    for e in errors:
        print(e)
    print(f"checked {len(files)} file(s): " + ("FAIL" if errors else "ok"))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
